//! Measurement instrumentation for the paper's Figure 2/3 overheads:
//! per-node network bytes (split by traffic class), storage gauges
//! (blockchain vs mempool), a RAM model, latency histograms, and the
//! wire-serializable [`StatsSnapshot`] the multi-process cluster's
//! control plane ships from each silo to the supervisor.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::crypto::NodeId;
use crate::util::codec::{Cursor, Decode, Encode};

/// Traffic classes so experiments can report consensus vs weight-transfer
/// bandwidth separately (DeFL's sending-bandwidth win comes from the
/// shared storage layer, §5.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Traffic {
    /// Consensus / control-plane messages (HotStuff, server RPCs).
    Consensus,
    /// Weight blob transfers (storage layer / parameter push-pull).
    Weights,
    /// Blockchain block gossip (baselines).
    Blocks,
}

impl Traffic {
    pub const ALL: [Traffic; 3] = [Traffic::Consensus, Traffic::Weights, Traffic::Blocks];

    pub fn name(&self) -> &'static str {
        match self {
            Traffic::Consensus => "consensus",
            Traffic::Weights => "weights",
            Traffic::Blocks => "blocks",
        }
    }
}

/// Per-node send/receive byte meters, with message counts split by
/// traffic class (the bytes/messages-per-round instrumentation behind
/// `BENCH_net.json`), plus lost-frame counters so fault-injection runs
/// can assert exactly how many frames the network ate.
#[derive(Debug, Clone, Default)]
pub struct NetMeter {
    sent: BTreeMap<(NodeId, Traffic), u64>,
    recv: BTreeMap<(NodeId, Traffic), u64>,
    msgs_sent: BTreeMap<(NodeId, Traffic), u64>,
    /// Frames lost in flight (targeted injection or random drop), keyed
    /// by SENDER — the bytes were metered as sent but never arrived.
    msgs_dropped: BTreeMap<(NodeId, Traffic), u64>,
    /// Frames rejected at the transport boundary because their
    /// `SignedFrame` envelope failed verification, keyed by the CLAIMED
    /// sender — the per-peer forgery/replay attribution signal.
    auth_fail: BTreeMap<(NodeId, Traffic), u64>,
    /// Frames dropped because the header's `from` field did not match
    /// the transport-level peer the frame arrived from, keyed by the
    /// ACTUAL peer (the hello-established connection identity) — the
    /// spoofed-transport-sender attribution signal. The simulator cannot
    /// produce these (its transport sender is the event's true origin);
    /// on TCP they pin `Inbound.from` to the connection's peer id.
    spoofed: BTreeMap<(NodeId, Traffic), u64>,
}

impl NetMeter {
    pub fn new() -> NetMeter {
        NetMeter::default()
    }

    pub fn on_send(&mut self, node: NodeId, class: Traffic, bytes: u64) {
        *self.sent.entry((node, class)).or_default() += bytes;
        *self.msgs_sent.entry((node, class)).or_default() += 1;
    }

    pub fn on_recv(&mut self, node: NodeId, class: Traffic, bytes: u64) {
        *self.recv.entry((node, class)).or_default() += bytes;
    }

    /// A frame from `node` was lost in flight.
    pub fn on_drop(&mut self, node: NodeId, class: Traffic) {
        *self.msgs_dropped.entry((node, class)).or_default() += 1;
    }

    /// A frame claiming to be from `claimed` failed signature
    /// verification at the receiving transport and was rejected.
    pub fn on_auth_fail(&mut self, claimed: NodeId, class: Traffic) {
        *self.auth_fail.entry((claimed, class)).or_default() += 1;
    }

    /// Auth rejections attributed to one claimed sender (all classes).
    pub fn auth_fail_by(&self, claimed: NodeId) -> u64 {
        Traffic::ALL
            .iter()
            .map(|c| self.auth_fail.get(&(claimed, *c)).copied().unwrap_or(0))
            .sum()
    }

    /// Cluster-wide auth rejections in one traffic class.
    pub fn auth_fail_class(&self, class: Traffic) -> u64 {
        self.auth_fail
            .iter()
            .filter(|((_, c), _)| *c == class)
            .map(|(_, v)| *v)
            .sum()
    }

    pub fn auth_fail_total(&self) -> u64 {
        self.auth_fail.values().sum()
    }

    /// The transport peer `peer` delivered a frame whose header claimed
    /// a different sender; the frame was dropped before dispatch.
    pub fn on_spoof(&mut self, peer: NodeId, class: Traffic) {
        *self.spoofed.entry((peer, class)).or_default() += 1;
    }

    /// Spoofed-sender drops attributed to one transport peer (all
    /// classes). Unlike `auth_fail_by`, the key is always the REAL peer
    /// the connection was hello-established with, never the forged id.
    pub fn spoofed_by(&self, peer: NodeId) -> u64 {
        Traffic::ALL
            .iter()
            .map(|c| self.spoofed.get(&(peer, *c)).copied().unwrap_or(0))
            .sum()
    }

    pub fn spoofed_total(&self) -> u64 {
        self.spoofed.values().sum()
    }

    /// Cluster-wide frames lost in one traffic class.
    pub fn dropped_class(&self, class: Traffic) -> u64 {
        self.msgs_dropped
            .iter()
            .filter(|((_, c), _)| *c == class)
            .map(|(_, v)| *v)
            .sum()
    }

    pub fn dropped_total(&self) -> u64 {
        self.msgs_dropped.values().sum()
    }

    pub fn sent_by(&self, node: NodeId) -> u64 {
        Traffic::ALL
            .iter()
            .map(|c| self.sent.get(&(node, *c)).copied().unwrap_or(0))
            .sum()
    }

    pub fn recv_by(&self, node: NodeId) -> u64 {
        Traffic::ALL
            .iter()
            .map(|c| self.recv.get(&(node, *c)).copied().unwrap_or(0))
            .sum()
    }

    pub fn sent_class(&self, class: Traffic) -> u64 {
        self.sent
            .iter()
            .filter(|((_, c), _)| *c == class)
            .map(|(_, v)| *v)
            .sum()
    }

    pub fn total_sent(&self) -> u64 {
        self.sent.values().sum()
    }

    pub fn total_recv(&self) -> u64 {
        self.recv.values().sum()
    }

    pub fn msgs_sent_by(&self, node: NodeId) -> u64 {
        Traffic::ALL
            .iter()
            .map(|c| self.msgs_sent.get(&(node, *c)).copied().unwrap_or(0))
            .sum()
    }

    /// Cluster-wide messages sent in one traffic class.
    pub fn msgs_class(&self, class: Traffic) -> u64 {
        self.msgs_sent
            .iter()
            .filter(|((_, c), _)| *c == class)
            .map(|(_, v)| *v)
            .sum()
    }

    pub fn msgs_total(&self) -> u64 {
        self.msgs_sent.values().sum()
    }

    /// Max over nodes of sent bytes — the "leader hot spot" detectability
    /// signal the paper cites against Swarm Learning (§2).
    pub fn max_node_sent(&self) -> u64 {
        let nodes: std::collections::BTreeSet<NodeId> =
            self.sent.keys().map(|(n, _)| *n).collect();
        nodes.into_iter().map(|n| self.sent_by(n)).max().unwrap_or(0)
    }

    pub fn merge(&mut self, other: &NetMeter) {
        for (k, v) in &other.sent {
            *self.sent.entry(*k).or_default() += v;
        }
        for (k, v) in &other.recv {
            *self.recv.entry(*k).or_default() += v;
        }
        for (k, v) in &other.msgs_sent {
            *self.msgs_sent.entry(*k).or_default() += v;
        }
        for (k, v) in &other.msgs_dropped {
            *self.msgs_dropped.entry(*k).or_default() += v;
        }
        for (k, v) in &other.auth_fail {
            *self.auth_fail.entry(*k).or_default() += v;
        }
        for (k, v) in &other.spoofed {
            *self.spoofed.entry(*k).or_default() += v;
        }
    }
}

/// Pull-protocol serve accounting for one peer, as shipped over the
/// cluster control plane (the metrics surface of the per-peer serve
/// budgets: how many reply bytes this node served the peer, and how many
/// of the peer's fetch requests the budgets denied).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PeerServe {
    pub peer: NodeId,
    pub bytes_served: u64,
    pub reqs_throttled: u64,
}

impl Encode for PeerServe {
    fn encode(&self, out: &mut Vec<u8>) {
        self.peer.encode(out);
        self.bytes_served.encode(out);
        self.reqs_throttled.encode(out);
    }
    fn encoded_len(&self) -> usize {
        4 + 8 + 8
    }
}

impl Decode for PeerServe {
    fn decode(cur: &mut Cursor<'_>) -> Result<Self> {
        Ok(PeerServe {
            peer: NodeId::decode(cur)?,
            bytes_served: u64::decode(cur)?,
            reqs_throttled: u64::decode(cur)?,
        })
    }
}

/// One node's observable state at a point in time, serializable for the
/// cluster control plane: each `defl-silo` process ships this in its
/// heartbeat frames, and `defl-supervisor` aggregates the snapshots into
/// the cluster-wide summary it prints at round boundaries and on exit.
///
/// The fields mirror `defl::NodeStats` + `defl::FetchStats` + the
/// consensus gauges; they are a *copy*, not a reference, so the snapshot
/// can cross the process boundary.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StatsSnapshot {
    pub node: NodeId,
    /// Synchronized training round r_round.
    pub round: u64,
    /// 1-based decided consensus height.
    pub decided_height: u64,
    /// Current HotStuff view.
    pub view: u64,
    /// Transactions executed / rejected by the Algorithm-2 replica.
    pub txs_executed: u64,
    pub txs_rejected: u64,
    /// Weight-pool gauges.
    pub pool_bytes: u64,
    pub pool_peak_bytes: u64,
    /// Pull-protocol health (cluster-wide visibility of `FetchStats`).
    pub fetches_sent: u64,
    pub blobs_recovered: u64,
    pub fetch_rotations: u64,
    pub fetch_gave_up: u64,
    pub serve_denied: u64,
    /// Event-driver health (zeros on the threads core and the
    /// simulator): loop iterations, parked-idle µs, frames appended to
    /// the coalescing buffers, and the flush writes that drained them.
    /// `drv_frames_coalesced / drv_flushes` is the frames-per-syscall
    /// ratio, and `drv_parked_us` against wall time is the poll-wait vs
    /// work split — the data the "shard the driver?" decision needs,
    /// shipped even when full tracing is off.
    pub drv_poll_iters: u64,
    pub drv_parked_us: u64,
    pub drv_frames_coalesced: u64,
    pub drv_flushes: u64,
    /// Per-peer serve-budget accounting, sorted by peer id.
    pub peer_serves: Vec<PeerServe>,
    /// Sustained-load driver: client update arrivals accepted / committed
    /// (zero when the load driver is off).
    pub load_arrivals: u64,
    pub load_commits: u64,
    /// Arrival→commit latency distribution (sparse on the wire; empty —
    /// 36 bytes — when the load driver is off). The supervisor merges
    /// these per-silo histograms into the cluster-wide p50/p99/p999 it
    /// prints, and diffs cumulative snapshots for windowed percentiles
    /// around a kill/rejoin.
    pub commit_hist: crate::load::hist::LatencyHistogram,
    /// The node finished its configured rounds.
    pub done: bool,
}

impl Encode for StatsSnapshot {
    fn encode(&self, out: &mut Vec<u8>) {
        self.node.encode(out);
        self.round.encode(out);
        self.decided_height.encode(out);
        self.view.encode(out);
        self.txs_executed.encode(out);
        self.txs_rejected.encode(out);
        self.pool_bytes.encode(out);
        self.pool_peak_bytes.encode(out);
        self.fetches_sent.encode(out);
        self.blobs_recovered.encode(out);
        self.fetch_rotations.encode(out);
        self.fetch_gave_up.encode(out);
        self.serve_denied.encode(out);
        self.drv_poll_iters.encode(out);
        self.drv_parked_us.encode(out);
        self.drv_frames_coalesced.encode(out);
        self.drv_flushes.encode(out);
        crate::util::codec::encode_list(&self.peer_serves, out);
        self.load_arrivals.encode(out);
        self.load_commits.encode(out);
        self.commit_hist.encode(out);
        self.done.encode(out);
    }
    fn encoded_len(&self) -> usize {
        4 + 8 * 16 + 4 + self.peer_serves.len() * 20
            + 8 * 2
            + self.commit_hist.encoded_len()
            + 1
    }
}

impl Decode for StatsSnapshot {
    fn decode(cur: &mut Cursor<'_>) -> Result<Self> {
        Ok(StatsSnapshot {
            node: NodeId::decode(cur)?,
            round: u64::decode(cur)?,
            decided_height: u64::decode(cur)?,
            view: u64::decode(cur)?,
            txs_executed: u64::decode(cur)?,
            txs_rejected: u64::decode(cur)?,
            pool_bytes: u64::decode(cur)?,
            pool_peak_bytes: u64::decode(cur)?,
            fetches_sent: u64::decode(cur)?,
            blobs_recovered: u64::decode(cur)?,
            fetch_rotations: u64::decode(cur)?,
            fetch_gave_up: u64::decode(cur)?,
            serve_denied: u64::decode(cur)?,
            drv_poll_iters: u64::decode(cur)?,
            drv_parked_us: u64::decode(cur)?,
            drv_frames_coalesced: u64::decode(cur)?,
            drv_flushes: u64::decode(cur)?,
            peer_serves: crate::util::codec::decode_list(cur)?,
            load_arrivals: u64::decode(cur)?,
            load_commits: u64::decode(cur)?,
            commit_hist: crate::load::hist::LatencyHistogram::decode(cur)?,
            done: bool::decode(cur)?,
        })
    }
}

/// Overlap-occupancy counters for the pipelined round engine: how often
/// the speculative next-round training was usable (its predicted W^LAST
/// basis matched the decided one) vs discarded, and how much training
/// time ran at all vs ran hidden behind the consensus/GST wait. Hits
/// publish a precomputed UPD the moment the round decides; discards cost
/// only wasted trainer time — speculative weights are never committed.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PipelineStats {
    /// Speculative updates published as-is when their round decided.
    pub spec_hits: u64,
    /// Speculative updates discarded: the aggregate basis changed under
    /// the trainer (late UPD, quorum without us, raced round).
    pub spec_discards: u64,
    /// Total training time spent, speculative or not (µs; simulated time
    /// in lite mode, wall time in full mode).
    pub train_busy_us: u64,
    /// Portion of training time that overlapped the consensus wait
    /// instead of extending the round (µs).
    pub train_overlap_us: u64,
}

impl PipelineStats {
    /// Fraction of resolved speculations that hit (0 when none resolved).
    pub fn hit_rate(&self) -> f64 {
        let resolved = self.spec_hits + self.spec_discards;
        if resolved == 0 {
            0.0
        } else {
            self.spec_hits as f64 / resolved as f64
        }
    }
}

/// Storage gauges per node: persistent chain bytes vs transient pool bytes.
#[derive(Debug, Clone, Default)]
pub struct StorageMeter {
    chain: BTreeMap<NodeId, u64>,
    pool: BTreeMap<NodeId, u64>,
    pool_peak: BTreeMap<NodeId, u64>,
}

impl StorageMeter {
    pub fn new() -> StorageMeter {
        StorageMeter::default()
    }

    pub fn chain_grow(&mut self, node: NodeId, bytes: u64) {
        *self.chain.entry(node).or_default() += bytes;
    }

    pub fn pool_set(&mut self, node: NodeId, bytes: u64) {
        self.pool.insert(node, bytes);
        let peak = self.pool_peak.entry(node).or_default();
        *peak = (*peak).max(bytes);
    }

    pub fn chain_bytes(&self, node: NodeId) -> u64 {
        self.chain.get(&node).copied().unwrap_or(0)
    }

    pub fn pool_bytes(&self, node: NodeId) -> u64 {
        self.pool.get(&node).copied().unwrap_or(0)
    }

    pub fn pool_peak(&self, node: NodeId) -> u64 {
        self.pool_peak.get(&node).copied().unwrap_or(0)
    }

    pub fn total_chain(&self) -> u64 {
        self.chain.values().sum()
    }

    /// Persistent storage per node averaged (the Figure 2 "Storage" bar:
    /// only the blockchain is measured, "for fairness" per §5.3).
    pub fn avg_chain(&self, n_nodes: usize) -> u64 {
        if n_nodes == 0 {
            0
        } else {
            self.total_chain() / n_nodes as u64
        }
    }
}

/// Resident-memory model: the paper's Figure 2 RAM bar. Counted parts:
/// weights resident per node (model + per-peer cached rounds) plus fixed
/// process overhead. GPU memory in the paper is identical across systems
/// (same model); we report the model bytes for completeness.
#[derive(Debug, Clone, Copy)]
pub struct RamModel {
    /// Fixed per-process overhead (runtime, executables, buffers).
    pub fixed_bytes: u64,
    /// One model's weight bytes (M).
    pub weight_bytes: u64,
}

impl RamModel {
    /// Resident bytes for a node holding `cached_weight_copies` weight
    /// vectors (e.g. DeFL: τ·n copies; FL client: 2).
    pub fn resident(&self, cached_weight_copies: usize) -> u64 {
        self.fixed_bytes + self.weight_bytes * cached_weight_copies as u64
    }
}

/// Fixed-boundary latency histogram (µs) with p50/p95/p99.
#[derive(Debug, Clone)]
pub struct Histogram {
    bounds: Vec<u64>,
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        // exponential bounds 1us .. ~17min
        let bounds: Vec<u64> = (0..40).map(|i| 1u64 << i).collect();
        Histogram {
            counts: vec![0; 41],
            bounds,
            total: 0,
            sum: 0,
            max: 0,
        }
    }

    pub fn record(&mut self, value_us: u64) {
        let idx = self
            .bounds
            .iter()
            .position(|b| value_us <= *b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += value_us as u128;
        self.max = self.max.max(value_us);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.bounds.len() { self.bounds[i] } else { self.max };
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn net_meter_accumulates_by_class() {
        let mut m = NetMeter::new();
        m.on_send(0, Traffic::Consensus, 100);
        m.on_send(0, Traffic::Weights, 4000);
        m.on_send(1, Traffic::Weights, 500);
        m.on_recv(1, Traffic::Weights, 4000);
        assert_eq!(m.sent_by(0), 4100);
        assert_eq!(m.sent_by(1), 500);
        assert_eq!(m.recv_by(1), 4000);
        assert_eq!(m.sent_class(Traffic::Weights), 4500);
        assert_eq!(m.total_sent(), 4600);
        assert_eq!(m.msgs_sent_by(0), 2);
        assert_eq!(m.msgs_class(Traffic::Weights), 2);
        assert_eq!(m.msgs_class(Traffic::Consensus), 1);
        assert_eq!(m.msgs_total(), 3);
        assert_eq!(m.max_node_sent(), 4100);
    }

    #[test]
    fn net_meter_merge() {
        let mut a = NetMeter::new();
        a.on_send(0, Traffic::Blocks, 10);
        a.on_drop(0, Traffic::Blocks);
        let mut b = NetMeter::new();
        b.on_send(0, Traffic::Blocks, 5);
        b.on_recv(2, Traffic::Consensus, 7);
        b.on_drop(1, Traffic::Weights);
        a.merge(&b);
        assert_eq!(a.sent_by(0), 15);
        assert_eq!(a.recv_by(2), 7);
        assert_eq!(a.dropped_total(), 2);
        assert_eq!(a.dropped_class(Traffic::Weights), 1);
    }

    #[test]
    fn auth_failures_attributed_per_peer() {
        let mut m = NetMeter::new();
        assert_eq!(m.auth_fail_total(), 0);
        m.on_auth_fail(2, Traffic::Weights);
        m.on_auth_fail(2, Traffic::Weights);
        m.on_auth_fail(2, Traffic::Consensus);
        m.on_auth_fail(0, Traffic::Consensus);
        assert_eq!(m.auth_fail_by(2), 3);
        assert_eq!(m.auth_fail_by(0), 1);
        assert_eq!(m.auth_fail_by(1), 0);
        assert_eq!(m.auth_fail_class(Traffic::Weights), 2);
        assert_eq!(m.auth_fail_total(), 4);
        // merge folds in the other meter's attributions.
        let mut other = NetMeter::new();
        other.on_auth_fail(2, Traffic::Blocks);
        m.merge(&other);
        assert_eq!(m.auth_fail_by(2), 4);
        assert_eq!(m.auth_fail_total(), 5);
    }

    #[test]
    fn spoofed_frames_attributed_to_the_transport_peer() {
        let mut m = NetMeter::new();
        assert_eq!(m.spoofed_total(), 0);
        // Peer 3 forged two senders; both drops land on peer 3.
        m.on_spoof(3, Traffic::Weights);
        m.on_spoof(3, Traffic::Consensus);
        m.on_spoof(1, Traffic::Blocks);
        assert_eq!(m.spoofed_by(3), 2);
        assert_eq!(m.spoofed_by(1), 1);
        assert_eq!(m.spoofed_by(0), 0);
        assert_eq!(m.spoofed_total(), 3);
        // Spoof drops are transport-level and never bleed into the
        // signature-rejection attribution.
        assert_eq!(m.auth_fail_total(), 0);
        let mut other = NetMeter::new();
        other.on_spoof(3, Traffic::Weights);
        m.merge(&other);
        assert_eq!(m.spoofed_by(3), 3);
        assert_eq!(m.spoofed_total(), 4);
    }

    #[test]
    fn dropped_frames_counted_per_class() {
        let mut m = NetMeter::new();
        assert_eq!(m.dropped_total(), 0);
        m.on_drop(3, Traffic::Weights);
        m.on_drop(3, Traffic::Weights);
        m.on_drop(1, Traffic::Consensus);
        assert_eq!(m.dropped_class(Traffic::Weights), 2);
        assert_eq!(m.dropped_class(Traffic::Consensus), 1);
        assert_eq!(m.dropped_total(), 3);
    }

    #[test]
    fn storage_meter_chain_vs_pool() {
        let mut s = StorageMeter::new();
        s.chain_grow(0, 1000);
        s.chain_grow(0, 1000);
        s.pool_set(0, 300);
        s.pool_set(0, 120); // pool can shrink (τ-round GC)
        assert_eq!(s.chain_bytes(0), 2000);
        assert_eq!(s.pool_bytes(0), 120);
        assert_eq!(s.pool_peak(0), 300);
        assert_eq!(s.avg_chain(2), 1000);
    }

    #[test]
    fn ram_model_counts_copies() {
        let ram = RamModel { fixed_bytes: 1_000_000, weight_bytes: 40_000 };
        assert_eq!(ram.resident(2), 1_080_000);
        assert!(ram.resident(20) > ram.resident(2));
    }

    #[test]
    fn stats_snapshot_roundtrips_exactly() {
        let snap = StatsSnapshot {
            node: 3,
            round: 7,
            decided_height: 21,
            view: 25,
            txs_executed: 80,
            txs_rejected: 2,
            pool_bytes: 4096,
            pool_peak_bytes: 8192,
            fetches_sent: 5,
            blobs_recovered: 4,
            fetch_rotations: 1,
            fetch_gave_up: 0,
            serve_denied: 3,
            drv_poll_iters: 55_000,
            drv_parked_us: 1_200_000,
            drv_frames_coalesced: 640,
            drv_flushes: 90,
            peer_serves: vec![
                PeerServe { peer: 0, bytes_served: 1024, reqs_throttled: 0 },
                PeerServe { peer: 2, bytes_served: 0, reqs_throttled: 3 },
            ],
            load_arrivals: 120,
            load_commits: 117,
            commit_hist: {
                let mut h = crate::load::hist::LatencyHistogram::new();
                for v in [150_000u64, 180_000, 220_000, 900_000] {
                    h.record(v);
                }
                h
            },
            done: true,
        };
        let bytes = snap.to_bytes();
        assert_eq!(bytes.len(), snap.encoded_len(), "encoded_len mismatch");
        assert_eq!(StatsSnapshot::from_bytes(&bytes).unwrap(), snap);
        // Truncations must error, never panic (the supervisor decodes
        // bytes a child process controls).
        for cut in 0..bytes.len() {
            assert!(StatsSnapshot::from_bytes(&bytes[..cut]).is_err());
        }
        let empty = StatsSnapshot::default();
        assert_eq!(
            StatsSnapshot::from_bytes(&empty.to_bytes()).unwrap(),
            empty
        );
    }

    #[test]
    fn pipeline_stats_hit_rate() {
        let mut p = PipelineStats::default();
        assert_eq!(p.hit_rate(), 0.0, "no resolutions yet");
        p.spec_hits = 3;
        p.spec_discards = 1;
        assert!((p.hit_rate() - 0.75).abs() < 1e-12);
        p.train_busy_us = 400;
        p.train_overlap_us = 300;
        assert!(p.train_overlap_us <= p.train_busy_us);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::new();
        for v in [1u64, 2, 3, 10, 100, 1000, 10_000, 100_000] {
            h.record(v);
        }
        assert_eq!(h.count(), 8);
        assert!(h.quantile(0.5) <= h.quantile(0.95));
        assert!(h.quantile(0.95) <= h.quantile(1.0));
        assert!(h.mean() > 0.0);
    }
}
