//! The pairwise-distance engine behind native Multi-Krum.
//!
//! The O(n²·D) squared-distance matrix dominates every native
//! aggregation. Two engines compute it:
//!
//! * **Exact** — the pinned per-pair reference: each pair's difference is
//!   accumulated in f64, exactly as [`pairwise_sq_dists_seq`] does. Large
//!   inputs stripe the pair list across the shared worker pool
//!   ([`crate::util::workers`]); per-pair arithmetic is untouched, so the
//!   result is bit-identical to the sequential reference regardless of
//!   thread count.
//! * **Gram** — the fast path, mirroring the L1 Pallas kernel
//!   (python/compile/kernels/pairwise.py): per-row squared norms are
//!   computed once and d²(i, j) = ‖i‖² + ‖j‖² − 2·⟨i, j⟩ is derived from
//!   a cache-blocked dot-product kernel. Rows are walked in
//!   [`ROW_BLOCK`]-row tiles over [`D_TILE`]-element slabs (the rust
//!   analogue of the kernel's VMEM block schedule), and the innermost
//!   contraction keeps [`LANES`] independent f32 partial sums so the
//!   compiler auto-vectorizes it; tiles fold into f64. Block tasks are
//!   distributed over the worker pool for large inputs.
//!
//! Exactness contract: Gram trades bit-identity for throughput. Its error
//! is bounded relative to the norm scale (‖i‖² + ‖j‖²), NOT relative to
//! d² itself — for near-identical rows the subtraction cancels and the
//! relative-to-d² error is unbounded, which is inherent to the Gram trick
//! (the Pallas artifact has the same property, and Krum only consumes the
//! matrix through sums and rankings of well-separated values). Callers
//! that need bit-exact distances pick [`DistEngine::Exact`] or set
//! `DEFL_KRUM_EXACT=1` to force it process-wide in `Auto` mode.

use std::sync::OnceLock;

use crate::util::workers::{self, ScopedJob, WorkerPool};

/// Flat row-major n×n squared-distance matrix (symmetric, zero diagonal).
/// One allocation, contiguous rows — replaces the `Vec<Vec<f32>>` of the
/// per-pair era so score selection streams each row without pointer
/// chasing.
#[derive(Debug, Clone, PartialEq)]
pub struct DistMatrix {
    n: usize,
    data: Vec<f32>,
}

impl DistMatrix {
    pub fn zeros(n: usize) -> DistMatrix {
        DistMatrix { n, data: vec![0.0; n * n] }
    }

    /// Number of rows (= columns).
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.n + j]
    }

    /// Row `i` as a contiguous slice of length n.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.n..(i + 1) * self.n]
    }

    #[inline]
    fn set_sym(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.n + j] = v;
        self.data[j * self.n + i] = v;
    }

    /// Copy out as nested rows (tests / debugging against the reference).
    pub fn to_nested(&self) -> Vec<Vec<f32>> {
        (0..self.n).map(|i| self.row(i).to_vec()).collect()
    }
}

/// Which distance implementation to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DistEngine {
    /// Gram when the work bound warrants it, Exact otherwise;
    /// `DEFL_KRUM_EXACT=1` forces Exact process-wide.
    Auto,
    /// Per-pair f64 accumulation, bit-identical to
    /// [`pairwise_sq_dists_seq`] (pool-parallel over pairs when large).
    Exact,
    /// Blocked Gram kernel on the calling thread.
    GramSeq,
    /// Blocked Gram kernel with tiles on the shared worker pool.
    GramPool,
}

/// One pair's squared distance, f64-accumulated exactly like the original
/// sequential loop (shared by the sequential and parallel exact drivers
/// so the two are bit-identical by construction).
#[inline]
pub(crate) fn pair_sq_dist(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b.iter()) {
        let d = (*x - *y) as f64;
        acc += d * d;
    }
    acc as f32
}

/// Sequential reference for the pairwise distance matrix (kept public so
/// tests can pin both engines against it).
pub fn pairwise_sq_dists_seq<R: AsRef<[f32]>>(rows: &[R]) -> Vec<Vec<f32>> {
    let n = rows.len();
    let mut d2 = vec![vec![0.0f32; n]; n];
    for i in 0..n {
        for j in (i + 1)..n {
            let d = pair_sq_dist(rows[i].as_ref(), rows[j].as_ref());
            d2[i][j] = d;
            d2[j][i] = d;
        }
    }
    d2
}

/// Below this many multiply-adds `Auto` stays on the exact per-pair path:
/// it is numerically exact and beats tile setup at tiny sizes.
pub(crate) const GRAM_WORK_MIN: usize = 1 << 16;

/// Below this many multiply-adds a single thread beats pool dispatch
/// (same constant the per-pair path used for its spawn threshold).
pub(crate) const POOL_WORK_MIN: usize = 1 << 21;

/// Independent f32 partial sums in the inner contraction — wide enough
/// for the compiler to lower onto SIMD lanes.
const LANES: usize = 8;

/// D-slab in f32 elements (16 KiB per row-tile): the 2·[`ROW_BLOCK`]
/// row-tiles a block task touches stay cache-resident while the slab is
/// contracted, cutting memory traffic ~ROW_BLOCK× vs the per-pair path.
const D_TILE: usize = 4096;

/// Rows per block tile on each side of the Gram contraction.
const ROW_BLOCK: usize = 4;

/// Dot product of one D-slab: [`LANES`] f32 partials folded into f64.
#[inline]
fn dot_tile(a: &[f32], b: &[f32]) -> f64 {
    let mut lanes = [0.0f32; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (pa, pb) in ca.by_ref().zip(cb.by_ref()) {
        for ((acc, x), y) in lanes.iter_mut().zip(pa).zip(pb) {
            *acc += *x * *y;
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += *x * *y;
    }
    lanes.iter().map(|&x| x as f64).sum::<f64>() + tail as f64
}

/// ‖a‖² with the same tiling as the Gram contraction.
fn sq_norm(a: &[f32]) -> f64 {
    let mut total = 0.0f64;
    for tile in a.chunks(D_TILE) {
        total += dot_tile(tile, tile);
    }
    total
}

/// Raw pointer to the flat matrix, sendable across pool workers.
///
/// Safety: every (i, j) upper-triangle cell belongs to exactly one block
/// task (see [`gram_block`]'s pair enumeration), so concurrent tasks
/// write disjoint cells.
#[derive(Clone, Copy)]
struct MatPtr {
    data: *mut f32,
    n: usize,
}

unsafe impl Send for MatPtr {}
unsafe impl Sync for MatPtr {}

impl MatPtr {
    /// # Safety
    /// Caller guarantees (i, j) is written by no other concurrent task
    /// and i, j < n.
    #[inline]
    unsafe fn set_sym(self, i: usize, j: usize, v: f32) {
        *self.data.add(i * self.n + j) = v;
        *self.data.add(j * self.n + i) = v;
    }
}

/// Contract one (a, b) row-block pair over all D-slabs and write its
/// distances. Diagonal blocks (a == b) only enumerate i < j.
fn gram_block<R: AsRef<[f32]> + Sync>(
    rows: &[R],
    norms: &[f64],
    dim: usize,
    a: usize,
    b: usize,
    out: MatPtr,
) {
    let n = rows.len();
    let i0 = a * ROW_BLOCK;
    let i1 = (i0 + ROW_BLOCK).min(n);
    let j0 = b * ROW_BLOCK;
    let j1 = (j0 + ROW_BLOCK).min(n);
    let mut acc = [[0.0f64; ROW_BLOCK]; ROW_BLOCK];
    let mut off = 0;
    while off < dim {
        let end = (off + D_TILE).min(dim);
        for i in i0..i1 {
            let ti = &rows[i].as_ref()[off..end];
            let jstart = if a == b { (i + 1).max(j0) } else { j0 };
            for j in jstart..j1 {
                let tj = &rows[j].as_ref()[off..end];
                acc[i - i0][j - j0] += dot_tile(ti, tj);
            }
        }
        off = end;
    }
    for i in i0..i1 {
        let jstart = if a == b { (i + 1).max(j0) } else { j0 };
        for j in jstart..j1 {
            let g = acc[i - i0][j - j0];
            // Clamp: cancellation can drive a mathematically non-negative
            // distance a hair below zero.
            let d2 = (norms[i] + norms[j] - 2.0 * g).max(0.0) as f32;
            // SAFETY: this (a, b) task owns the (i, j) cell exclusively.
            unsafe { out.set_sym(i, j, d2) };
        }
    }
}

fn pairwise_gram<R: AsRef<[f32]> + Sync>(rows: &[R], pool: Option<&WorkerPool>) -> DistMatrix {
    let n = rows.len();
    let dim = rows[0].as_ref().len();
    let mut m = DistMatrix::zeros(n);

    let mut norms = vec![0.0f64; n];
    match pool {
        Some(pool) if n > 1 && pool.workers() > 1 => {
            workers::for_each_chunk_mut(pool, &mut norms, pool.workers(), |off, chunk| {
                for (k, v) in chunk.iter_mut().enumerate() {
                    *v = sq_norm(rows[off + k].as_ref());
                }
            });
        }
        _ => {
            for (v, row) in norms.iter_mut().zip(rows.iter()) {
                *v = sq_norm(row.as_ref());
            }
        }
    }

    // Upper-triangle row-block pairs; each is one independent task.
    let nb = n.div_ceil(ROW_BLOCK);
    let blocks: Vec<(usize, usize)> =
        (0..nb).flat_map(|a| (a..nb).map(move |b| (a, b))).collect();
    let ptr = MatPtr { data: m.data.as_mut_ptr(), n };
    match pool {
        Some(pool) if blocks.len() > 1 && pool.workers() > 1 => {
            let shares = pool.workers().min(blocks.len());
            let chunk = blocks.len().div_ceil(shares);
            let norms = &norms;
            let jobs: Vec<ScopedJob<'_>> = blocks
                .chunks(chunk)
                .map(|share| {
                    let job: ScopedJob<'_> = Box::new(move || {
                        for &(a, b) in share {
                            gram_block(rows, norms, dim, a, b, ptr);
                        }
                    });
                    job
                })
                .collect();
            pool.scope(jobs);
        }
        _ => {
            for &(a, b) in &blocks {
                gram_block(rows, &norms, dim, a, b, ptr);
            }
        }
    }
    m
}

fn pairwise_exact<R: AsRef<[f32]> + Sync>(rows: &[R]) -> DistMatrix {
    let n = rows.len();
    let dim = rows[0].as_ref().len();
    let mut m = DistMatrix::zeros(n);
    let pairs: Vec<(usize, usize)> =
        (0..n).flat_map(|i| ((i + 1)..n).map(move |j| (i, j))).collect();
    let n_pairs = pairs.len();
    // Don't touch (and lazily spawn) the pool unless the work warrants it.
    let pool = if n_pairs >= 2 && n_pairs * dim >= POOL_WORK_MIN {
        Some(workers::global())
    } else {
        None
    };
    let Some(pool) = pool.filter(|p| p.workers() >= 2) else {
        for &(i, j) in &pairs {
            let d = pair_sq_dist(rows[i].as_ref(), rows[j].as_ref());
            m.set_sym(i, j, d);
        }
        return m;
    };
    // Stripe the pair list across the pool; every worker writes disjoint
    // slots of its own output chunk, per-pair arithmetic untouched.
    let chunk = n_pairs.div_ceil(pool.workers().min(n_pairs));
    let mut dists = vec![0.0f32; n_pairs];
    {
        let jobs: Vec<ScopedJob<'_>> = pairs
            .chunks(chunk)
            .zip(dists.chunks_mut(chunk))
            .map(|(pair_chunk, out_chunk)| {
                let job: ScopedJob<'_> = Box::new(move || {
                    for (&(i, j), out) in pair_chunk.iter().zip(out_chunk.iter_mut()) {
                        *out = pair_sq_dist(rows[i].as_ref(), rows[j].as_ref());
                    }
                });
                job
            })
            .collect();
        pool.scope(jobs);
    }
    for (&(i, j), d) in pairs.iter().zip(dists) {
        m.set_sym(i, j, d);
    }
    m
}

fn exact_forced() -> bool {
    static FORCED: OnceLock<bool> = OnceLock::new();
    *FORCED.get_or_init(|| {
        matches!(
            std::env::var("DEFL_KRUM_EXACT").as_deref().map(str::trim),
            Ok("1") | Ok("true")
        )
    })
}

/// Pairwise squared distances with the `Auto` engine (see [`DistEngine`]).
pub fn pairwise_dists<R: AsRef<[f32]> + Sync>(rows: &[R]) -> DistMatrix {
    pairwise_dists_with(rows, DistEngine::Auto)
}

/// Pairwise squared distances with an explicit engine choice.
pub fn pairwise_dists_with<R: AsRef<[f32]> + Sync>(rows: &[R], engine: DistEngine) -> DistMatrix {
    let n = rows.len();
    if n < 2 {
        return DistMatrix::zeros(n);
    }
    let dim = rows[0].as_ref().len();
    let work = n * (n - 1) / 2 * dim;
    let engine = match engine {
        DistEngine::Auto => {
            if exact_forced() || work < GRAM_WORK_MIN {
                DistEngine::Exact
            } else if work >= POOL_WORK_MIN {
                DistEngine::GramPool
            } else {
                DistEngine::GramSeq
            }
        }
        e => e,
    };
    match engine {
        DistEngine::Exact => pairwise_exact(rows),
        DistEngine::GramSeq => pairwise_gram(rows, None),
        DistEngine::GramPool => pairwise_gram(rows, Some(workers::global())),
        DistEngine::Auto => unreachable!("Auto resolved above"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{forall, gens};
    use crate::util::Pcg;

    fn cluster(rng: &mut Pcg, n: usize, d: usize, spread: f32) -> Vec<Vec<f32>> {
        let center = gens::f32_vec(rng, d, 1.0);
        (0..n)
            .map(|_| center.iter().map(|c| c + rng.normal_f32(0.0, spread)).collect())
            .collect()
    }

    fn f64_norm2(row: &[f32]) -> f64 {
        row.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    #[test]
    fn dist_matrix_layout_row_at_nested() {
        let mut m = DistMatrix::zeros(3);
        m.set_sym(0, 2, 5.0);
        m.set_sym(1, 2, 7.0);
        assert_eq!(m.n(), 3);
        assert_eq!(m.at(0, 2), 5.0);
        assert_eq!(m.at(2, 0), 5.0);
        assert_eq!(m.row(2), &[5.0, 7.0, 0.0]);
        assert_eq!(m.to_nested()[1], vec![0.0, 0.0, 7.0]);
    }

    #[test]
    fn every_engine_is_symmetric_with_zero_diag() {
        let mut rng = Pcg::seeded(1);
        let rows = cluster(&mut rng, 6, 50, 1.0);
        for engine in [DistEngine::Auto, DistEngine::Exact, DistEngine::GramSeq, DistEngine::GramPool] {
            let d2 = pairwise_dists_with(&rows, engine);
            for i in 0..6 {
                assert_eq!(d2.at(i, i), 0.0, "{engine:?} diag");
                for j in 0..6 {
                    assert!((d2.at(i, j) - d2.at(j, i)).abs() < 1e-6, "{engine:?} sym");
                }
            }
        }
    }

    #[test]
    fn exact_engine_bit_identical_to_sequential_reference() {
        // Force the pool-parallel exact path (work > POOL_WORK_MIN) and
        // compare bit patterns, not approximate values.
        let mut rng = Pcg::seeded(44);
        let n = 12;
        let d = POOL_WORK_MIN / (12 * 11 / 2) + 17;
        let rows = cluster(&mut rng, n, d, 1.0);
        let par = pairwise_dists_with(&rows, DistEngine::Exact);
        let seq = pairwise_sq_dists_seq(&rows);
        for i in 0..n {
            for j in 0..n {
                assert_eq!(
                    par.at(i, j).to_bits(),
                    seq[i][j].to_bits(),
                    "bit mismatch at ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn auto_small_inputs_take_the_exact_path_identically() {
        let mut rng = Pcg::seeded(45);
        let rows = cluster(&mut rng, 5, 64, 0.5);
        let a = pairwise_dists(&rows);
        let b = pairwise_sq_dists_seq(&rows);
        assert_eq!(a.to_nested(), b);
    }

    #[test]
    fn degenerate_sizes() {
        let none: Vec<Vec<f32>> = Vec::new();
        assert_eq!(pairwise_dists(&none).n(), 0);
        let one = vec![vec![1.0f32, 2.0]];
        let m = pairwise_dists(&one);
        assert_eq!(m.n(), 1);
        assert_eq!(m.at(0, 0), 0.0);
    }

    #[test]
    fn prop_gram_matches_exact_within_norm_scaled_tolerance() {
        // The exactness contract: Gram error is bounded relative to the
        // norm scale ‖i‖² + ‖j‖², across (n, D, spread) regimes from
        // tight clusters (heavy cancellation) to well-separated rows.
        forall(
            "gram-vs-exact",
            17,
            12,
            6,
            |rng, size| {
                let n = 3 + rng.gen_usize(8);
                let d = 32 + rng.gen_usize(size * 700 + 1);
                let spread = [0.01f32, 0.3, 3.0][rng.gen_usize(3)];
                cluster(rng, n, d, spread)
            },
            |rows| {
                let n = rows.len();
                let seq = pairwise_sq_dists_seq(rows);
                let norms: Vec<f64> = rows.iter().map(|r| f64_norm2(r)).collect();
                for engine in [DistEngine::GramSeq, DistEngine::GramPool] {
                    let g = pairwise_dists_with(rows, engine);
                    for i in 0..n {
                        for j in 0..n {
                            let tol = 1e-4 * (norms[i] + norms[j] + 1.0);
                            let err = (g.at(i, j) as f64 - seq[i][j] as f64).abs();
                            prop_assert!(
                                err <= tol,
                                "{engine:?} ({i},{j}): err {err:.3e} > tol {tol:.3e} \
                                 (d2 {}, dim {})",
                                seq[i][j],
                                rows[0].len()
                            );
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn gram_handles_non_multiple_block_and_tile_sizes() {
        // n not a multiple of ROW_BLOCK, dim not a multiple of LANES or
        // D_TILE: remainders must still be contracted.
        let mut rng = Pcg::seeded(9);
        let rows = cluster(&mut rng, ROW_BLOCK * 2 + 3, D_TILE + LANES + 5, 0.7);
        let g = pairwise_dists_with(&rows, DistEngine::GramSeq);
        let s = pairwise_sq_dists_seq(&rows);
        for i in 0..rows.len() {
            for j in 0..rows.len() {
                let tol = 1e-4 * (f64_norm2(&rows[i]) + f64_norm2(&rows[j]) + 1.0);
                assert!(
                    (g.at(i, j) as f64 - s[i][j] as f64).abs() <= tol,
                    "({i},{j}): {} vs {}",
                    g.at(i, j),
                    s[i][j]
                );
            }
        }
    }
}
