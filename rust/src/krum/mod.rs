//! Native Krum / Multi-Krum (Blanchard et al. 2017), the DeFL weight
//! filter (§3.2).
//!
//! The hot path uses the AOT artifact (L1 Pallas Gram kernel inside the L2
//! aggregation graph, executed through [`crate::runtime`]); this module is
//! the arbitrary-(n, f) reference used for (a) cross-checking the artifact
//! in tests, (b) configurations outside the exported combos, and (c) the
//! pure-rust baselines.
//!
//! Rows are accepted as any `AsRef<[f32]>` (e.g. `Vec<f32>`, `&[f32]`,
//! [`crate::weights::Weights`]), so the DeFL node aggregates straight out
//! of the weight pool without a per-row copy. The O(n²·D) distance matrix
//! — the dominant cost of the native fallback — is computed on multiple
//! threads for large inputs, with results bit-identical to the sequential
//! reference (each pair's f64 accumulation is untouched; only the pairs
//! are distributed).

use anyhow::{bail, Result};

/// Result of a Multi-Krum aggregation.
#[derive(Debug, Clone)]
pub struct KrumOutput {
    /// Weighted mean of the selected rows.
    pub aggregate: Vec<f32>,
    /// Krum score per row (lower = more trustworthy).
    pub scores: Vec<f32>,
    /// 1.0 for selected rows, 0.0 for filtered rows.
    pub mask: Vec<f32>,
}

/// One pair's squared distance, f64-accumulated exactly like the original
/// sequential loop (shared by the sequential and parallel drivers so the
/// two are bit-identical by construction).
#[inline]
fn pair_sq_dist(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b.iter()) {
        let d = (*x - *y) as f64;
        acc += d * d;
    }
    acc as f32
}

/// Sequential reference for the pairwise distance matrix (kept public so
/// tests can pin the parallel path against it).
pub fn pairwise_sq_dists_seq<R: AsRef<[f32]>>(rows: &[R]) -> Vec<Vec<f32>> {
    let n = rows.len();
    let mut d2 = vec![vec![0.0f32; n]; n];
    for i in 0..n {
        for j in (i + 1)..n {
            let d = pair_sq_dist(rows[i].as_ref(), rows[j].as_ref());
            d2[i][j] = d;
            d2[j][i] = d;
        }
    }
    d2
}

/// Below this many multiply-adds the thread-spawn overhead dominates and
/// the sequential path wins.
const PAR_WORK_THRESHOLD: usize = 1 << 21;

/// Pairwise squared distances between rows (n × n, symmetric, zero diag).
///
/// Large inputs are chunked over `std::thread::scope` worker threads;
/// per-pair arithmetic is identical to [`pairwise_sq_dists_seq`], so the
/// result is bit-identical regardless of thread count.
pub fn pairwise_sq_dists<R: AsRef<[f32]> + Sync>(rows: &[R]) -> Vec<Vec<f32>> {
    let n = rows.len();
    if n < 2 {
        return pairwise_sq_dists_seq(rows);
    }
    let dim = rows[0].as_ref().len();
    let n_pairs = n * (n - 1) / 2;
    let threads = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
    if n_pairs * dim < PAR_WORK_THRESHOLD || threads < 2 || n_pairs < 2 {
        return pairwise_sq_dists_seq(rows);
    }

    // Enumerate the upper triangle and stripe it across workers; every
    // worker writes disjoint (i, j) results into its own chunk.
    let pairs: Vec<(usize, usize)> = (0..n)
        .flat_map(|i| ((i + 1)..n).map(move |j| (i, j)))
        .collect();
    let workers = threads.min(n_pairs);
    let chunk = n_pairs.div_ceil(workers);
    let mut dists = vec![0.0f32; n_pairs];

    std::thread::scope(|scope| {
        for (pair_chunk, out_chunk) in pairs.chunks(chunk).zip(dists.chunks_mut(chunk)) {
            scope.spawn(move || {
                for ((i, j), out) in pair_chunk.iter().zip(out_chunk.iter_mut()) {
                    *out = pair_sq_dist(rows[*i].as_ref(), rows[*j].as_ref());
                }
            });
        }
    });

    let mut d2 = vec![vec![0.0f32; n]; n];
    for ((i, j), d) in pairs.into_iter().zip(dists) {
        d2[i][j] = d;
        d2[j][i] = d;
    }
    d2
}

/// Krum scores: for each row, the sum of squared distances to its
/// n − f − 2 closest other rows.
pub fn krum_scores<R: AsRef<[f32]> + Sync>(rows: &[R], f: usize) -> Result<Vec<f32>> {
    let n = rows.len();
    if n < f + 3 {
        bail!("krum needs n - f - 2 >= 1 (n={n}, f={f})");
    }
    let dim = rows[0].as_ref().len();
    if let Some(bad) = rows.iter().position(|r| r.as_ref().len() != dim) {
        bail!("krum: row {bad} has dim {} != {dim}", rows[bad].as_ref().len());
    }
    let closest = n - f - 2;
    let d2 = pairwise_sq_dists(rows);
    let mut scores = Vec::with_capacity(n);
    for i in 0..n {
        let mut dists: Vec<f32> = (0..n).filter(|&j| j != i).map(|j| d2[i][j]).collect();
        dists.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        scores.push(dists[..closest].iter().sum());
    }
    Ok(scores)
}

/// Multi-Krum: FedAvg (weighted by `sample_weights`) over the `m` rows
/// with the smallest Krum scores. Matches python/compile/aggregate.py
/// (ties broken by index, like argsort).
pub fn multi_krum<R: AsRef<[f32]> + Sync>(
    rows: &[R],
    sample_weights: &[f32],
    f: usize,
    m: usize,
) -> Result<KrumOutput> {
    let n = rows.len();
    if m == 0 || m > n {
        bail!("multi-krum: m={m} out of range 1..={n}");
    }
    if sample_weights.len() != n {
        bail!("multi-krum: {} sample weights for {n} rows", sample_weights.len());
    }
    let scores = krum_scores(rows, f)?;

    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        scores[a]
            .partial_cmp(&scores[b])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut mask = vec![0.0f32; n];
    for &i in &order[..m] {
        mask[i] = 1.0;
    }

    let dim = rows[0].as_ref().len();
    let mut aggregate = vec![0.0f32; dim];
    let mut total_w = 0.0f64;
    for i in 0..n {
        if mask[i] == 0.0 {
            continue;
        }
        let w = sample_weights[i] as f64;
        total_w += w;
        for (acc, x) in aggregate.iter_mut().zip(rows[i].as_ref().iter()) {
            *acc += (w * *x as f64) as f32;
        }
    }
    let denom = total_w.max(1e-12) as f32;
    for a in aggregate.iter_mut() {
        *a /= denom;
    }
    Ok(KrumOutput { aggregate, scores, mask })
}

/// Plain FedAvg over all rows (the FL/SL aggregation rule).
pub fn fedavg<R: AsRef<[f32]>>(rows: &[R], sample_weights: &[f32]) -> Result<Vec<f32>> {
    let n = rows.len();
    if n == 0 {
        bail!("fedavg: no rows");
    }
    if sample_weights.len() != n {
        bail!("fedavg: weight arity");
    }
    let dim = rows[0].as_ref().len();
    let mut out = vec![0.0f64; dim];
    let mut total = 0.0f64;
    for (row, &w) in rows.iter().zip(sample_weights.iter()) {
        let row = row.as_ref();
        if row.len() != dim {
            bail!("fedavg: ragged rows");
        }
        total += w as f64;
        for (acc, x) in out.iter_mut().zip(row.iter()) {
            *acc += w as f64 * *x as f64;
        }
    }
    let denom = total.max(1e-12);
    Ok(out.into_iter().map(|x| (x / denom) as f32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{forall, gens};
    use crate::util::Pcg;
    use crate::weights::Weights;

    fn cluster(rng: &mut Pcg, n: usize, d: usize, spread: f32) -> Vec<Vec<f32>> {
        let center = gens::f32_vec(rng, d, 1.0);
        (0..n)
            .map(|_| {
                center
                    .iter()
                    .map(|c| c + rng.normal_f32(0.0, spread))
                    .collect()
            })
            .collect()
    }

    #[test]
    fn distances_symmetric_zero_diag() {
        let mut rng = Pcg::seeded(1);
        let rows = cluster(&mut rng, 6, 50, 1.0);
        let d2 = pairwise_sq_dists(&rows);
        for i in 0..6 {
            assert_eq!(d2[i][i], 0.0);
            for j in 0..6 {
                assert!((d2[i][j] - d2[j][i]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn parallel_distances_bit_identical_to_sequential() {
        // Force the parallel path (work > PAR_WORK_THRESHOLD) and compare
        // bit patterns, not approximate values.
        let mut rng = Pcg::seeded(44);
        let n = 12;
        let d = PAR_WORK_THRESHOLD / (12 * 11 / 2) + 17;
        let rows = cluster(&mut rng, n, d, 1.0);
        let par = pairwise_sq_dists(&rows);
        let seq = pairwise_sq_dists_seq(&rows);
        for i in 0..n {
            for j in 0..n {
                assert_eq!(
                    par[i][j].to_bits(),
                    seq[i][j].to_bits(),
                    "bit mismatch at ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn small_inputs_take_the_sequential_path_identically() {
        let mut rng = Pcg::seeded(45);
        let rows = cluster(&mut rng, 5, 64, 0.5);
        let a = pairwise_sq_dists(&rows);
        let b = pairwise_sq_dists_seq(&rows);
        assert_eq!(a, b);
    }

    #[test]
    fn rows_may_be_weights_handles() {
        // The pool path: aggregate straight from Weights without to_vec.
        let mut rng = Pcg::seeded(46);
        let vecs = cluster(&mut rng, 5, 32, 0.1);
        let handles: Vec<Weights> = vecs.iter().map(|v| Weights::new(v.clone())).collect();
        let a = multi_krum(&vecs, &[1.0; 5], 1, 4).unwrap();
        let b = multi_krum(&handles, &[1.0; 5], 1, 4).unwrap();
        assert_eq!(a.aggregate, b.aggregate);
        assert_eq!(a.scores, b.scores);
        assert_eq!(a.mask, b.mask);
    }

    #[test]
    fn outlier_gets_worst_score() {
        let mut rng = Pcg::seeded(2);
        let mut rows = cluster(&mut rng, 7, 64, 0.1);
        rows[3] = gens::f32_vec(&mut rng, 64, 50.0);
        let scores = krum_scores(&rows, 1).unwrap();
        let worst = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(worst, 3);
    }

    #[test]
    fn multi_krum_filters_outlier_and_averages_rest() {
        let mut rng = Pcg::seeded(3);
        let mut rows = cluster(&mut rng, 4, 32, 0.01);
        rows[0] = rows[0].iter().map(|x| -3.0 * x).collect();
        let out = multi_krum(&rows, &[1.0; 4], 1, 3).unwrap();
        assert_eq!(out.mask[0], 0.0);
        assert_eq!(out.mask.iter().sum::<f32>(), 3.0);
        // aggregate ≈ mean of rows 1..3
        let manual = fedavg(&rows[1..], &[1.0; 3]).unwrap();
        for (a, b) in out.aggregate.iter().zip(manual.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn fedavg_weighted() {
        let rows = vec![vec![1.0f32; 4], vec![4.0f32; 4]];
        let avg = fedavg(&rows, &[3.0, 1.0]).unwrap();
        for x in avg {
            assert!((x - 1.75).abs() < 1e-6);
        }
    }

    #[test]
    fn arity_errors() {
        let rows = vec![vec![0.0f32; 4]; 4];
        assert!(krum_scores(&rows, 2).is_err()); // n-f-2 = 0
        assert!(multi_krum(&rows, &[1.0; 3], 1, 3).is_err()); // weights arity
        assert!(multi_krum(&rows, &[1.0; 4], 1, 0).is_err()); // m = 0
        assert!(multi_krum(&rows, &[1.0; 4], 1, 5).is_err()); // m > n
        let ragged = vec![vec![0.0f32; 4], vec![0.0f32; 3]];
        assert!(krum_scores(&ragged, 0).is_err());
    }

    #[test]
    fn prop_mask_selects_exactly_m() {
        forall("mask-m", 11, 40, 10, |rng, size| {
            let n = 4 + rng.gen_usize(7);
            let f = rng.gen_usize((n - 3).max(1).min(n / 2) + 1);
            let m = 1 + rng.gen_usize(n - f.max(1));
            let d = 4 + size;
            let rows: Vec<Vec<f32>> = (0..n).map(|_| gens::f32_vec(rng, d, 1.0)).collect();
            (rows, f, m)
        }, |(rows, f, m)| {
            let out = match multi_krum(rows, &vec![1.0; rows.len()], *f, *m) {
                Ok(o) => o,
                Err(e) => return Err(format!("unexpected error: {e}")),
            };
            prop_assert!(
                out.mask.iter().sum::<f32>() as usize == *m,
                "mask selected {} != m {}", out.mask.iter().sum::<f32>(), m
            );
            prop_assert!(out.aggregate.iter().all(|x| x.is_finite()), "non-finite agg");
            Ok(())
        });
    }

    #[test]
    fn prop_aggregate_within_selected_hull_bounds() {
        forall("agg-bounds", 13, 30, 8, |rng, size| {
            let n = 5 + rng.gen_usize(5);
            let d = 2 + size;
            let rows: Vec<Vec<f32>> = (0..n).map(|_| gens::f32_vec(rng, d, 2.0)).collect();
            rows
        }, |rows| {
            let n = rows.len();
            let out = multi_krum(rows, &vec![1.0; n], 1, n - 1).map_err(|e| e.to_string())?;
            for k in 0..rows[0].len() {
                let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                for i in 0..n {
                    if out.mask[i] > 0.0 {
                        lo = lo.min(rows[i][k]);
                        hi = hi.max(rows[i][k]);
                    }
                }
                prop_assert!(
                    out.aggregate[k] >= lo - 1e-4 && out.aggregate[k] <= hi + 1e-4,
                    "coordinate {k} escapes hull"
                );
            }
            Ok(())
        });
    }
}
