//! Native Krum / Multi-Krum (Blanchard et al. 2017), the DeFL weight
//! filter (§3.2).
//!
//! The deployment hot path uses the AOT artifact (L1 Pallas Gram kernel
//! inside the L2 aggregation graph, executed through [`crate::runtime`]);
//! this module is the arbitrary-(n, f) engine used for (a) configurations
//! outside the exported combos, (b) cross-checking the artifact in tests,
//! and (c) the pure-rust baselines.
//!
//! Rows are accepted as any `AsRef<[f32]> + Sync` (e.g. `Vec<f32>`,
//! `&[f32]`, [`crate::weights::Weights`]), so the DeFL node aggregates
//! straight out of the weight pool without a per-row copy.
//!
//! ## Engine dispatch
//!
//! The O(n²·D) distance matrix is served by [`dist`]:
//!
//! * `Auto` (the default for [`krum_scores`] / [`multi_krum`]) runs the
//!   blocked **Gram** kernel — norms once, d² = ‖i‖² + ‖j‖² − 2⟨i,j⟩ from
//!   cache-tiled, auto-vectorized dot products — on the shared persistent
//!   worker pool ([`crate::util::workers`]) above ~2M multiply-adds,
//!   single-threaded below, and falls back to the exact per-pair path
//!   under ~64K multiply-adds where tile setup isn't worth it.
//! * The **Exact** engine keeps PR 1's contract: per-pair f64
//!   accumulation bit-identical to [`pairwise_sq_dists_seq`], pool-striped
//!   for large inputs. `DEFL_KRUM_EXACT=1` forces it process-wide — the
//!   escape hatch for configurations that must reproduce the sequential
//!   reference bit-for-bit.
//!
//! The worker pool is lazily spawned on first large aggregation and lives
//! for the process — no per-call thread spawns anywhere on this path.
//!
//! Score selection uses `select_nth_unstable` (only the n−f−2 closest
//! neighbours matter) with the selected prefix re-sorted, so scores stay
//! bit-identical to the full-sort reference over the same matrix. The
//! Multi-Krum aggregation itself is one fused weighted pass over
//! dim-chunks, pool-parallel for large models, with per-coordinate f64
//! accumulation that is independent of the chunking.

pub mod dist;

pub use dist::{pairwise_dists, pairwise_dists_with, pairwise_sq_dists_seq, DistEngine, DistMatrix};

use std::cmp::Ordering;

use anyhow::{bail, Result};

use crate::util::workers;

/// Result of a Multi-Krum aggregation.
#[derive(Debug, Clone)]
pub struct KrumOutput {
    /// Weighted mean of the selected rows.
    pub aggregate: Vec<f32>,
    /// Krum score per row (lower = more trustworthy).
    pub scores: Vec<f32>,
    /// 1.0 for selected rows, 0.0 for filtered rows.
    pub mask: Vec<f32>,
}

#[inline]
fn fcmp(a: &f32, b: &f32) -> Ordering {
    a.partial_cmp(b).unwrap_or(Ordering::Equal)
}

/// Krum scores with the `Auto` distance engine: for each row, the sum of
/// squared distances to its n − f − 2 closest other rows.
pub fn krum_scores<R: AsRef<[f32]> + Sync>(rows: &[R], f: usize) -> Result<Vec<f32>> {
    krum_scores_with(rows, f, DistEngine::Auto)
}

/// Krum scores over an explicitly chosen distance engine.
pub fn krum_scores_with<R: AsRef<[f32]> + Sync>(
    rows: &[R],
    f: usize,
    engine: DistEngine,
) -> Result<Vec<f32>> {
    let n = rows.len();
    if n < f + 3 {
        bail!("krum needs n - f - 2 >= 1 (n={n}, f={f})");
    }
    let dim = rows[0].as_ref().len();
    if let Some(bad) = rows.iter().position(|r| r.as_ref().len() != dim) {
        bail!("krum: row {bad} has dim {} != {dim}", rows[bad].as_ref().len());
    }
    let closest = n - f - 2;
    let d2 = pairwise_dists_with(rows, engine);
    let mut scores = Vec::with_capacity(n);
    let mut scratch = vec![0.0f32; n - 1];
    for i in 0..n {
        let row = d2.row(i);
        // The row minus its zero diagonal entry (distances to OTHER rows).
        scratch[..i].copy_from_slice(&row[..i]);
        scratch[i..].copy_from_slice(&row[i + 1..]);
        // Partial selection: only the `closest` smallest matter. The
        // selected prefix is re-sorted and summed in ascending order, so
        // the score is bit-identical to the full-sort reference.
        let (lo, mid, _hi) = scratch.select_nth_unstable_by(closest - 1, fcmp);
        lo.sort_unstable_by(fcmp);
        let mut s = 0.0f32;
        for x in lo.iter() {
            s += *x;
        }
        s += *mid;
        scores.push(s);
    }
    Ok(scores)
}

/// Work bound above which the fused aggregation pass fans out dim-chunks
/// over the worker pool.
const AGG_POOL_WORK_MIN: usize = dist::POOL_WORK_MIN;

/// Fused weighted mean over `sel` rows: one pass per dim-chunk, f64
/// accumulation per coordinate. Chunks run on the pool for large models;
/// each coordinate's accumulation order is fixed (row order), so the
/// result is independent of the chunking.
fn weighted_mean<R: AsRef<[f32]> + Sync>(
    rows: &[R],
    sel: &[usize],
    sample_weights: &[f32],
    dim: usize,
) -> Vec<f32> {
    let mut total = 0.0f64;
    for &i in sel {
        total += sample_weights[i] as f64;
    }
    let denom = total.max(1e-12);
    let mut out = vec![0.0f32; dim];
    let accumulate = |start: usize, chunk: &mut [f32]| {
        let mut acc = vec![0.0f64; chunk.len()];
        for &i in sel {
            let w = sample_weights[i] as f64;
            let row = &rows[i].as_ref()[start..start + chunk.len()];
            for (a, x) in acc.iter_mut().zip(row) {
                *a += w * *x as f64;
            }
        }
        for (o, a) in chunk.iter_mut().zip(acc) {
            *o = (a / denom) as f32;
        }
    };
    if sel.len() * dim >= AGG_POOL_WORK_MIN {
        let pool = workers::global();
        workers::for_each_chunk_mut(pool, &mut out, pool.workers(), accumulate);
    } else {
        accumulate(0, &mut out);
    }
    out
}

/// Multi-Krum with the `Auto` engine: FedAvg (weighted by
/// `sample_weights`) over the `m` rows with the smallest Krum scores.
/// Matches python/compile/aggregate.py (ties broken by index, like
/// argsort).
pub fn multi_krum<R: AsRef<[f32]> + Sync>(
    rows: &[R],
    sample_weights: &[f32],
    f: usize,
    m: usize,
) -> Result<KrumOutput> {
    multi_krum_with(rows, sample_weights, f, m, DistEngine::Auto)
}

/// Multi-Krum over an explicitly chosen distance engine.
pub fn multi_krum_with<R: AsRef<[f32]> + Sync>(
    rows: &[R],
    sample_weights: &[f32],
    f: usize,
    m: usize,
    engine: DistEngine,
) -> Result<KrumOutput> {
    let n = rows.len();
    if m == 0 || m > n {
        bail!("multi-krum: m={m} out of range 1..={n}");
    }
    if sample_weights.len() != n {
        bail!("multi-krum: {} sample weights for {n} rows", sample_weights.len());
    }
    let scores = krum_scores_with(rows, f, engine)?;

    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| fcmp(&scores[a], &scores[b]).then(a.cmp(&b)));
    let mut mask = vec![0.0f32; n];
    for &i in &order[..m] {
        mask[i] = 1.0;
    }
    let mut sel = order[..m].to_vec();
    sel.sort_unstable();

    let dim = rows[0].as_ref().len();
    let aggregate = weighted_mean(rows, &sel, sample_weights, dim);
    Ok(KrumOutput { aggregate, scores, mask })
}

/// Plain FedAvg over all rows (the FL/SL aggregation rule), through the
/// same fused pass as Multi-Krum's aggregation.
pub fn fedavg<R: AsRef<[f32]> + Sync>(rows: &[R], sample_weights: &[f32]) -> Result<Vec<f32>> {
    let n = rows.len();
    if n == 0 {
        bail!("fedavg: no rows");
    }
    if sample_weights.len() != n {
        bail!("fedavg: weight arity");
    }
    let dim = rows[0].as_ref().len();
    if let Some(bad) = rows.iter().position(|r| r.as_ref().len() != dim) {
        bail!("fedavg: ragged rows (row {bad})");
    }
    let sel: Vec<usize> = (0..n).collect();
    Ok(weighted_mean(rows, &sel, sample_weights, dim))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{forall, gens};
    use crate::util::Pcg;
    use crate::weights::Weights;

    fn cluster(rng: &mut Pcg, n: usize, d: usize, spread: f32) -> Vec<Vec<f32>> {
        let center = gens::f32_vec(rng, d, 1.0);
        (0..n)
            .map(|_| {
                center
                    .iter()
                    .map(|c| c + rng.normal_f32(0.0, spread))
                    .collect()
            })
            .collect()
    }

    #[test]
    fn rows_may_be_weights_handles() {
        // The pool path: aggregate straight from Weights without to_vec.
        let mut rng = Pcg::seeded(46);
        let vecs = cluster(&mut rng, 5, 32, 0.1);
        let handles: Vec<Weights> = vecs.iter().map(|v| Weights::new(v.clone())).collect();
        let a = multi_krum(&vecs, &[1.0; 5], 1, 4).unwrap();
        let b = multi_krum(&handles, &[1.0; 5], 1, 4).unwrap();
        assert_eq!(a.aggregate, b.aggregate);
        assert_eq!(a.scores, b.scores);
        assert_eq!(a.mask, b.mask);
    }

    #[test]
    fn outlier_gets_worst_score() {
        let mut rng = Pcg::seeded(2);
        let mut rows = cluster(&mut rng, 7, 64, 0.1);
        rows[3] = gens::f32_vec(&mut rng, 64, 50.0);
        let scores = krum_scores(&rows, 1).unwrap();
        let worst = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(worst, 3);
    }

    #[test]
    fn partial_selection_bit_identical_to_full_sort_reference() {
        // Same distance matrix in, same scores out: select_nth + prefix
        // sort must reproduce the full-sort reference exactly.
        let mut rng = Pcg::seeded(21);
        for (n, f) in [(5usize, 1usize), (9, 2), (12, 4), (8, 0)] {
            let rows = cluster(&mut rng, n, 40, 1.0);
            let scores = krum_scores_with(&rows, f, DistEngine::Exact).unwrap();
            let d2 = pairwise_sq_dists_seq(&rows);
            let closest = n - f - 2;
            for i in 0..n {
                let mut dists: Vec<f32> =
                    (0..n).filter(|&j| j != i).map(|j| d2[i][j]).collect();
                dists.sort_by(fcmp);
                let expect: f32 = dists[..closest].iter().sum();
                assert_eq!(
                    scores[i].to_bits(),
                    expect.to_bits(),
                    "row {i} of (n={n}, f={f}): {} vs {}",
                    scores[i],
                    expect
                );
            }
        }
    }

    #[test]
    fn gram_and_exact_engines_agree_on_selection() {
        // Numerics differ in low bits; the FILTER decision must not.
        // Spread 0.5 keeps inlier score gaps orders of magnitude above
        // the Gram kernel's norm-scaled error, so mask equality is
        // deterministic, while the outliers stay unambiguous.
        let mut rng = Pcg::seeded(23);
        let mut rows = cluster(&mut rng, 9, 600, 0.5);
        rows[4] = gens::f32_vec(&mut rng, 600, 20.0);
        rows[7] = rows[7].iter().map(|x| x * -5.0).collect();
        let sw = vec![1.0f32; 9];
        let exact = multi_krum_with(&rows, &sw, 2, 6, DistEngine::Exact).unwrap();
        for engine in [DistEngine::GramSeq, DistEngine::GramPool] {
            let gram = multi_krum_with(&rows, &sw, 2, 6, engine).unwrap();
            assert_eq!(gram.mask, exact.mask, "{engine:?} mask diverged");
            assert_eq!(gram.mask[4], 0.0);
            assert_eq!(gram.mask[7], 0.0);
            for (a, b) in gram.aggregate.iter().zip(exact.aggregate.iter()) {
                assert!((a - b).abs() < 1e-3, "{engine:?} agg diverged: {a} vs {b}");
            }
        }
    }

    #[test]
    fn multi_krum_filters_outlier_and_averages_rest() {
        let mut rng = Pcg::seeded(3);
        let mut rows = cluster(&mut rng, 4, 32, 0.01);
        rows[0] = rows[0].iter().map(|x| -3.0 * x).collect();
        let out = multi_krum(&rows, &[1.0; 4], 1, 3).unwrap();
        assert_eq!(out.mask[0], 0.0);
        assert_eq!(out.mask.iter().sum::<f32>(), 3.0);
        // aggregate ≈ mean of rows 1..3
        let manual = fedavg(&rows[1..], &[1.0; 3]).unwrap();
        for (a, b) in out.aggregate.iter().zip(manual.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn fused_aggregation_independent_of_chunking() {
        // weighted_mean must yield the same bits through the pool chunks
        // as through the single inline chunk.
        let mut rng = Pcg::seeded(29);
        let dim = AGG_POOL_WORK_MIN / 3 + 41;
        let rows: Vec<Vec<f32>> = (0..4).map(|_| gens::f32_vec(&mut rng, dim, 1.0)).collect();
        let sw = [1.0f32, 2.0, 0.5, 3.0];
        let sel = [0usize, 1, 3];
        let pooled = weighted_mean(&rows, &sel, &sw, dim);
        // Inline reference: same per-coordinate accumulation, one chunk.
        let denom: f64 = sel.iter().map(|&i| sw[i] as f64).sum::<f64>().max(1e-12);
        for (k, got) in pooled.iter().enumerate() {
            let mut acc = 0.0f64;
            for &i in &sel {
                acc += sw[i] as f64 * rows[i][k] as f64;
            }
            assert_eq!(got.to_bits(), ((acc / denom) as f32).to_bits(), "coord {k}");
        }
    }

    #[test]
    fn fedavg_weighted() {
        let rows = vec![vec![1.0f32; 4], vec![4.0f32; 4]];
        let avg = fedavg(&rows, &[3.0, 1.0]).unwrap();
        for x in avg {
            assert!((x - 1.75).abs() < 1e-6);
        }
    }

    #[test]
    fn arity_errors() {
        let rows = vec![vec![0.0f32; 4]; 4];
        assert!(krum_scores(&rows, 2).is_err()); // n-f-2 = 0
        assert!(multi_krum(&rows, &[1.0; 3], 1, 3).is_err()); // weights arity
        assert!(multi_krum(&rows, &[1.0; 4], 1, 0).is_err()); // m = 0
        assert!(multi_krum(&rows, &[1.0; 4], 1, 5).is_err()); // m > n
        let ragged = vec![vec![0.0f32; 4], vec![0.0f32; 3]];
        assert!(krum_scores(&ragged, 0).is_err());
        assert!(fedavg(&ragged, &[1.0; 2]).is_err());
    }

    #[test]
    fn prop_mask_selects_exactly_m() {
        forall("mask-m", 11, 40, 10, |rng, size| {
            let n = 4 + rng.gen_usize(7);
            let f = rng.gen_usize((n - 3).clamp(1, n / 2) + 1);
            let m = 1 + rng.gen_usize(n - f.max(1));
            let d = 4 + size;
            let rows: Vec<Vec<f32>> = (0..n).map(|_| gens::f32_vec(rng, d, 1.0)).collect();
            (rows, f, m)
        }, |(rows, f, m)| {
            let out = match multi_krum(rows, &vec![1.0; rows.len()], *f, *m) {
                Ok(o) => o,
                Err(e) => return Err(format!("unexpected error: {e}")),
            };
            prop_assert!(
                out.mask.iter().sum::<f32>() as usize == *m,
                "mask selected {} != m {}", out.mask.iter().sum::<f32>(), m
            );
            prop_assert!(out.aggregate.iter().all(|x| x.is_finite()), "non-finite agg");
            Ok(())
        });
    }

    #[test]
    fn prop_aggregate_within_selected_hull_bounds() {
        forall("agg-bounds", 13, 30, 8, |rng, size| {
            let n = 5 + rng.gen_usize(5);
            let d = 2 + size;
            let rows: Vec<Vec<f32>> = (0..n).map(|_| gens::f32_vec(rng, d, 2.0)).collect();
            rows
        }, |rows| {
            let n = rows.len();
            let out = multi_krum(rows, &vec![1.0; n], 1, n - 1).map_err(|e| e.to_string())?;
            for k in 0..rows[0].len() {
                let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                for i in 0..n {
                    if out.mask[i] > 0.0 {
                        lo = lo.min(rows[i][k]);
                        hi = hi.max(rows[i][k]);
                    }
                }
                prop_assert!(
                    out.aggregate[k] >= lo - 1e-4 && out.aggregate[k] <= hi + 1e-4,
                    "coordinate {k} escapes hull"
                );
            }
            Ok(())
        });
    }
}
