//! Minimal blockchain substrate for the SL / Biscotti baselines.
//!
//! The paper's baselines sit on third-party chains (Ethereum / FISCO);
//! what their comparison needs is the *costs* a chain imposes: every
//! replica stores every historical block, and blocks are gossiped to all
//! peers. This module provides hash-chained blocks, per-chain byte
//! accounting, verification, and the SL-style hash-based leader election.

use anyhow::{bail, Result};

use crate::crypto::{Digest, NodeId};
use crate::util::codec::{Cursor, Decode, Encode};

/// A block: height, parent link, proposer, opaque payload.
#[derive(Debug, Clone, PartialEq)]
pub struct ChainBlock {
    pub height: u64,
    pub parent: Digest,
    pub proposer: NodeId,
    pub payload: Vec<u8>,
}

impl ChainBlock {
    pub fn digest(&self) -> Digest {
        Digest::of_bytes(&self.to_bytes())
    }
}

impl Encode for ChainBlock {
    fn encode(&self, out: &mut Vec<u8>) {
        self.height.encode(out);
        self.parent.encode(out);
        self.proposer.encode(out);
        self.payload.encode(out);
    }
    fn encoded_len(&self) -> usize {
        8 + 32 + 4 + self.payload.encoded_len()
    }
}

impl Decode for ChainBlock {
    fn decode(cur: &mut Cursor<'_>) -> Result<Self> {
        Ok(ChainBlock {
            height: u64::decode(cur)?,
            parent: Digest::decode(cur)?,
            proposer: NodeId::decode(cur)?,
            payload: Vec::<u8>::decode(cur)?,
        })
    }
}

/// A replica's full copy of the chain — the storage cost the paper's
/// Figure 2 measures ("we measure the storage usage of only the
/// blockchain for fairness", §5.3).
#[derive(Debug, Default)]
pub struct Chain {
    blocks: Vec<ChainBlock>,
    bytes: u64,
}

impl Chain {
    pub fn new() -> Chain {
        Chain::default()
    }

    pub fn tip(&self) -> Digest {
        self.blocks.last().map(|b| b.digest()).unwrap_or_else(Digest::zero)
    }

    pub fn height(&self) -> u64 {
        self.blocks.len() as u64
    }

    /// Append after verifying the hash link and height.
    pub fn append(&mut self, block: ChainBlock) -> Result<()> {
        if block.height != self.height() + 1 {
            bail!("chain: height {} != {}", block.height, self.height() + 1);
        }
        if block.parent != self.tip() {
            bail!("chain: parent mismatch at height {}", block.height);
        }
        self.bytes += block.encoded_len() as u64;
        self.blocks.push(block);
        Ok(())
    }

    /// Idempotent append: ignores blocks already on the chain.
    pub fn append_if_new(&mut self, block: ChainBlock) -> Result<bool> {
        if block.height <= self.height() {
            return Ok(false);
        }
        self.append(block)?;
        Ok(true)
    }

    pub fn get(&self, height: u64) -> Option<&ChainBlock> {
        if height == 0 {
            return None;
        }
        self.blocks.get(height as usize - 1)
    }

    /// Total persisted bytes (what the storage figure reports).
    pub fn bytes(&self) -> u64 {
        self.bytes
    }
}

/// SL-style leader election: hash of (tip, round) picks the round leader,
/// making the schedule unpredictable but chain-deterministic.
pub fn elect_leader(tip: &Digest, round: u64, n: usize) -> NodeId {
    let mut buf = Vec::with_capacity(40);
    tip.encode(&mut buf);
    round.encode(&mut buf);
    let h = Digest::of_bytes(&buf);
    let x = u64::from_le_bytes(h.0[..8].try_into().unwrap());
    (x % n as u64) as NodeId
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blk(height: u64, parent: Digest, payload: usize) -> ChainBlock {
        ChainBlock { height, parent, proposer: 0, payload: vec![7u8; payload] }
    }

    #[test]
    fn chain_links_verified() {
        let mut c = Chain::new();
        let b1 = blk(1, c.tip(), 10);
        c.append(b1.clone()).unwrap();
        assert_eq!(c.height(), 1);
        assert!(c.append(blk(3, c.tip(), 10)).is_err()); // height gap
        assert!(c.append(blk(2, Digest::zero(), 10)).is_err()); // bad parent
        c.append(blk(2, c.tip(), 20)).unwrap();
        assert_eq!(c.get(1).unwrap(), &b1);
        assert!(c.get(0).is_none());
        assert!(c.get(5).is_none());
    }

    #[test]
    fn bytes_accumulate_forever() {
        // The Biscotti storage failure mode: chains only grow.
        let mut c = Chain::new();
        let mut last = 0;
        for h in 1..=50 {
            c.append(blk(h, c.tip(), 1000)).unwrap();
            assert!(c.bytes() > last);
            last = c.bytes();
        }
        assert!(c.bytes() >= 50 * 1000);
    }

    #[test]
    fn append_if_new_is_idempotent() {
        let mut c = Chain::new();
        let b = blk(1, c.tip(), 5);
        assert!(c.append_if_new(b.clone()).unwrap());
        assert!(!c.append_if_new(b).unwrap());
        assert_eq!(c.height(), 1);
    }

    #[test]
    fn block_roundtrip() {
        let b = blk(4, Digest::of_bytes(b"p"), 17);
        let bytes = b.to_bytes();
        assert_eq!(bytes.len(), b.encoded_len());
        assert_eq!(ChainBlock::from_bytes(&bytes).unwrap(), b);
    }

    #[test]
    fn election_is_deterministic_and_spread() {
        let tip = Digest::of_bytes(b"tip");
        let n = 7;
        let mut hits = vec![0u32; n];
        for round in 0..700 {
            let l = elect_leader(&tip, round, n);
            assert_eq!(l, elect_leader(&tip, round, n));
            hits[l as usize] += 1;
        }
        for h in hits {
            assert!(h > 40, "leader election badly skewed: {h}");
        }
    }
}
