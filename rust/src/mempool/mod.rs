//! Decoupled storage layer (DeFL §3.4): a digest-addressed weight pool.
//!
//! Consensus transactions carry only `Digest`s; the blobs themselves live
//! here. The pool retains weights for at most τ ≥ 2 training rounds
//! (current + last, §4.3), so storage is Mτn regardless of T — the 100×
//! win over chain-based baselines in Figure 2. `gc(round)` drops
//! everything older than `round − τ + 1`.
//!
//! Entries are [`Weights`] handles: inserting a tensor the caller also
//! holds (trainer output, decoded blob) shares the allocation instead of
//! copying it, the content digest is taken from the tensor's cache (one
//! SHA-256 per tensor per process, not per layer), and `get` hands back
//! a cheap clone the aggregation path can keep across pool mutations.
//!
//! Both containers are SHARDED by digest with per-shard locks (and take
//! `&self`), so concurrent ingest — chunk reassembly from many peers,
//! fetch serving, speculative training reading rows while gc runs — no
//! longer serializes on one pool-wide lock. The `Arc<[f32]>`-backed
//! [`Weights`] handle makes every cross-shard move a pointer copy.
//! Byte gauges are atomics; `gc` short-circuits any shard whose minimum
//! round tag is already inside the retention horizon, so a no-op gc
//! touches zero entries (pinned by a unit test via [`WeightPool::gc_scanned`]).
//!
//! Large blobs arrive as [`BlobChunk`]s (see [`crate::defl::tx`]);
//! [`ChunkAssembler`] rebuilds them, verifies the claimed content digest
//! against the reassembled tensor, and hands the pool a whole blob.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::crypto::Digest;
use crate::defl::tx::{BlobChunk, WeightBlob};
use crate::weights::Weights;

/// Fixed shard count for both containers. A power of two so the shard
/// index is a mask of the digest's first byte; 16 comfortably exceeds
/// the worker-pool parallelism any one process runs with.
const SHARDS: usize = 16;

/// Shard index of a digest: SHA-256 output is uniform, so the first
/// byte alone spreads entries evenly.
fn shard_of(digest: &Digest) -> usize {
    (digest.0[0] as usize) & (SHARDS - 1)
}

/// A stored weight blob, tagged with the round it belongs to.
#[derive(Debug, Clone)]
struct Entry {
    round: u64,
    weights: Weights,
}

/// One lock's worth of the pool.
#[derive(Debug, Default)]
struct PoolShard {
    entries: BTreeMap<Digest, Entry>,
    /// Lower bound on the round tags in this shard (`u64::MAX` when
    /// empty). `put` maintains it exactly on insert; `gc` recomputes it
    /// when it scans. A re-insert that BUMPS an entry's round can leave
    /// this stale-low, which only costs one unnecessary scan — never a
    /// wrongly skipped reap.
    min_round: u64,
}

impl PoolShard {
    fn new() -> PoolShard {
        PoolShard { entries: BTreeMap::new(), min_round: u64::MAX }
    }
}

/// Content-addressed, round-tagged weight pool with τ-round retention,
/// sharded by digest for lock-free-in-practice concurrent access.
#[derive(Debug)]
pub struct WeightPool {
    tau: u64,
    shards: Vec<Mutex<PoolShard>>,
    /// Running byte gauge (4 bytes per f32 element), maintained
    /// incrementally by `put`/`gc`.
    bytes: AtomicU64,
    /// Peak bytes ever resident (RAM model input).
    peak_bytes: AtomicU64,
    /// Entries examined by `gc` scans since construction — the gc-cost
    /// meter the short-circuit test pins.
    gc_scanned: AtomicU64,
    /// Non-empty shards `gc` skipped because their `min_round` was
    /// already inside the retention horizon.
    gc_short_circuits: AtomicU64,
}

impl WeightPool {
    pub fn new(tau: usize) -> WeightPool {
        assert!(tau >= 2, "tau must cover current + last round");
        WeightPool {
            tau: tau as u64,
            shards: (0..SHARDS).map(|_| Mutex::new(PoolShard::new())).collect(),
            bytes: AtomicU64::new(0),
            peak_bytes: AtomicU64::new(0),
            gc_scanned: AtomicU64::new(0),
            gc_short_circuits: AtomicU64::new(0),
        }
    }

    fn shard(&self, digest: &Digest) -> std::sync::MutexGuard<'_, PoolShard> {
        self.shards[shard_of(digest)].lock().unwrap()
    }

    /// Insert a blob under its (cached) content digest. Returns the digest.
    /// Re-inserting identical content is a no-op (content addressing).
    pub fn put(&self, round: u64, weights: impl Into<Weights>) -> Digest {
        let weights = weights.into();
        let digest = weights.digest();
        let mut shard = self.shard(&digest);
        if let Some(prev) = shard.entries.get_mut(&digest) {
            // Same content seen again (e.g. re-broadcast): keep the newest
            // round tag so GC doesn't reap a still-referenced blob.
            prev.round = prev.round.max(round);
            return digest;
        }
        let sz = (weights.len() * 4) as u64;
        shard.min_round = shard.min_round.min(round);
        shard.entries.insert(digest, Entry { round, weights });
        drop(shard);
        let now = self.bytes.fetch_add(sz, Ordering::Relaxed) + sz;
        self.peak_bytes.fetch_max(now, Ordering::Relaxed);
        digest
    }

    /// Fetch a blob: a cheap handle clone that stays valid across later
    /// pool mutations (so aggregation never copies rows out).
    pub fn get(&self, digest: &Digest) -> Result<Weights> {
        match self.shard(digest).entries.get(digest) {
            Some(e) => Ok(e.weights.clone()),
            None => bail!("mempool: {} not present", digest.short()),
        }
    }

    /// Batch lookup for an aggregation row set. All-or-nothing: on any
    /// miss the error names every missing digest AND the full requested
    /// list, so a lost blob is diagnosable in one log line instead of n
    /// separate "not present" errors.
    pub fn get_many(&self, digests: &[Digest]) -> Result<Vec<Weights>> {
        let mut out = Vec::with_capacity(digests.len());
        let mut missing: Vec<String> = Vec::new();
        for d in digests {
            match self.shard(d).entries.get(d) {
                Some(e) => out.push(e.weights.clone()),
                None => missing.push(d.short()),
            }
        }
        if !missing.is_empty() {
            let wanted: Vec<String> = digests.iter().map(|d| d.short()).collect();
            bail!(
                "mempool: {}/{} digests missing [{}] of requested [{}]",
                missing.len(),
                digests.len(),
                missing.join(", "),
                wanted.join(", ")
            );
        }
        Ok(out)
    }

    pub fn contains(&self, digest: &Digest) -> bool {
        self.shard(digest).entries.contains_key(digest)
    }

    /// Round tag and tensor handle for one digest — what the pull
    /// protocol serves: the handle shares the pool's allocation, and the
    /// round tag lets the served chunks pass the requester's round
    /// horizon without inventing a round the server never saw.
    pub fn entry(&self, digest: &Digest) -> Option<(u64, Weights)> {
        self.shard(digest).entries.get(digest).map(|e| (e.round, e.weights.clone()))
    }

    /// Drop all blobs older than `current_round − τ + 1`. Shards whose
    /// tracked `min_round` is already inside the horizon are skipped
    /// without touching a single entry, so the steady-state gc (called
    /// every round advance, usually with nothing expired) is O(shards),
    /// not O(entries). The byte gauge is maintained incrementally
    /// (subtract what was reaped); the subtraction saturates so an
    /// accounting bug can never wrap the gauge to ~u64::MAX and poison
    /// every storage metric downstream.
    pub fn gc(&self, current_round: u64) {
        let keep_from = current_round.saturating_sub(self.tau - 1);
        let mut reaped = 0u64;
        for slot in &self.shards {
            let mut shard = slot.lock().unwrap();
            if shard.entries.is_empty() {
                continue;
            }
            if shard.min_round >= keep_from {
                self.gc_short_circuits.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            let mut scanned = 0u64;
            let mut min_round = u64::MAX;
            shard.entries.retain(|_, e| {
                scanned += 1;
                if e.round >= keep_from {
                    min_round = min_round.min(e.round);
                    true
                } else {
                    reaped += (e.weights.len() * 4) as u64;
                    false
                }
            });
            shard.min_round = min_round;
            self.gc_scanned.fetch_add(scanned, Ordering::Relaxed);
        }
        if reaped > 0 {
            let _ = self
                .bytes
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |b| {
                    Some(b.saturating_sub(reaped))
                });
        }
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().entries.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak_bytes.load(Ordering::Relaxed)
    }

    /// Total entries `gc` scans have examined (cost meter: stays flat
    /// while nothing is expired).
    pub fn gc_scanned(&self) -> u64 {
        self.gc_scanned.load(Ordering::Relaxed)
    }

    /// Non-empty shards `gc` skipped via the round-horizon short-circuit.
    pub fn gc_short_circuits(&self) -> u64 {
        self.gc_short_circuits.load(Ordering::Relaxed)
    }
}

/// A blob mid-reassembly. Segments are kept as received (offset →
/// payload) and only stitched into one buffer at completion, so memory
/// is charged for bytes actually RECEIVED — a tiny chunk claiming a
/// huge `total_bytes` cannot pin more than its own payload.
#[derive(Debug)]
struct PartialBlob {
    node: crate::crypto::NodeId,
    round: u64,
    total_bytes: u32,
    segments: HashMap<u32, Vec<u8>>,
    covered: u64,
}

/// One lock's worth of the assembler's partials.
#[derive(Debug, Default)]
struct AsmShard {
    partials: HashMap<(crate::crypto::NodeId, Digest), PartialBlob>,
}

/// Receiver side of chunked blob multicast: buffers [`BlobChunk`]s per
/// (transport sender, content digest), and returns the whole
/// [`WeightBlob`] once every byte is covered AND the reassembled tensor
/// hashes to the claimed digest.
///
/// Partials are sharded by digest like the pool, so reassembly streams
/// from many peers land on different locks; the per-SENDER byte budget
/// is global across shards (one flooder must not get `SHARDS` budgets)
/// and lives under its own small lock, always acquired after a shard
/// lock, never before.
///
/// Robustness contract (Byzantine peers control every chunk FIELD, but
/// not the transport-level `from` the embedding node passes in):
/// * partials are keyed by `(from, digest)`, so a Byzantine node
///   injecting forged chunks for an honest blob's digest only poisons
///   its OWN partial — the honest sender's stream reassembles untouched;
/// * memory is charged per received payload byte (never the claimed
///   total) against a PER-SENDER budget of `cap_bytes`, so one flooding
///   peer can exhaust only its own allowance, never an honest sender's;
/// * chunks landing outside the declared image, declaring an image the
///   budget could never admit, conflicting with the partial's total, or
///   tagged with a round beyond [`ChunkAssembler::set_round_horizon`]
///   are rejected with an error; with the horizon wired to the replica
///   round, junk partials age out of [`ChunkAssembler::gc`] within τ
///   rounds instead of pinning memory forever;
/// * duplicate offsets are idempotent; overlapping or corrupt payloads
///   survive until finalization, where the SHA-256 check rejects the
///   whole partial (content addressing is the single source of truth).
#[derive(Debug)]
pub struct ChunkAssembler {
    shards: Vec<Mutex<AsmShard>>,
    /// Buffered (received) segment bytes per transport sender — global
    /// across shards by design.
    sender_bytes: Mutex<HashMap<crate::crypto::NodeId, u64>>,
    /// Per-sender buffer budget.
    cap_bytes: u64,
    /// Highest acceptable chunk `round` tag (u64::MAX = no limit).
    round_horizon: AtomicU64,
    completed: AtomicU64,
    rejected: AtomicU64,
}

impl ChunkAssembler {
    pub fn new(cap_bytes: u64) -> ChunkAssembler {
        ChunkAssembler {
            shards: (0..SHARDS).map(|_| Mutex::new(AsmShard::default())).collect(),
            sender_bytes: Mutex::new(HashMap::new()),
            cap_bytes,
            round_horizon: AtomicU64::new(u64::MAX),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        }
    }

    fn shard(&self, digest: &Digest) -> std::sync::MutexGuard<'_, AsmShard> {
        self.shards[shard_of(digest)].lock().unwrap()
    }

    fn reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Cap the acceptable chunk `round` tag. The embedding node keeps
    /// this a small slack above its replica round so an attacker cannot
    /// park junk at `round = u64::MAX` where `gc` never reaps it.
    pub fn set_round_horizon(&self, horizon: u64) {
        self.round_horizon.store(horizon, Ordering::Relaxed);
    }

    /// Accept one chunk received from transport peer `from`.
    /// `Ok(Some(blob))` when this chunk completed the blob (digest
    /// already verified), `Ok(None)` while still partial.
    pub fn accept(
        &self,
        from: crate::crypto::NodeId,
        chunk: BlobChunk,
    ) -> Result<Option<WeightBlob>> {
        let BlobChunk { node, round, digest, total_bytes, offset, payload } = chunk;
        let total = total_bytes as u64;
        let end = offset as u64 + payload.len() as u64;
        if payload.is_empty() || end > total || total % 4 != 0 {
            self.reject();
            bail!(
                "chunk [{offset}, {end}) invalid for a {total}-byte blob {}",
                digest.short()
            );
        }
        let horizon = self.round_horizon.load(Ordering::Relaxed);
        if round > horizon {
            self.reject();
            bail!("chunk round {round} beyond horizon {horizon}");
        }
        // A claimed image the budget could never admit will never
        // complete: refuse it outright rather than buffering doomed
        // segments.
        if total > self.cap_bytes {
            self.reject();
            bail!(
                "chunk assembler: {} would exceed the {}-byte budget",
                digest.short(),
                self.cap_bytes
            );
        }
        let key = (from, digest);
        let mut shard = self.shard(&digest);
        // Duplicate/conflict checks come BEFORE the budget check so a
        // benign retransmit near the cap stays idempotent (Ok(None), not
        // an error) and never counts as a rejection.
        if let Some(p) = shard.partials.get_mut(&key) {
            if p.total_bytes != total_bytes {
                self.reject();
                bail!("chunk: conflicting total for {}", digest.short());
            }
            // Keep the newest round tag (re-broadcasts), like
            // `WeightPool::put`.
            p.round = p.round.max(round);
            if p.segments.contains_key(&offset) {
                return Ok(None); // duplicate chunk
            }
        }
        {
            let mut budgets = self.sender_bytes.lock().unwrap();
            let used = budgets.entry(from).or_default();
            if *used + payload.len() as u64 > self.cap_bytes {
                drop(budgets);
                self.reject();
                bail!(
                    "chunk assembler: sender {from} over its {}-byte budget",
                    self.cap_bytes
                );
            }
            *used += payload.len() as u64;
        }
        let p = shard.partials.entry(key).or_insert_with(|| PartialBlob {
            node,
            round,
            total_bytes,
            segments: HashMap::new(),
            covered: 0,
        });
        p.covered += payload.len() as u64;
        p.segments.insert(offset, payload);
        if p.covered < total {
            return Ok(None);
        }
        // Complete (or overlapped into apparent completeness): stitch the
        // segments and let the content digest decide.
        let p = shard.partials.remove(&key).unwrap();
        drop(shard);
        self.credit(from, p.covered);
        let mut buf = vec![0u8; total as usize];
        for (off, seg) in &p.segments {
            let start = *off as usize;
            buf[start..start + seg.len()].copy_from_slice(seg);
        }
        let weights = Weights::from_le_bytes(&buf)?;
        if weights.digest() != digest {
            self.reject();
            bail!("reassembled blob does not hash to {}", digest.short());
        }
        self.completed.fetch_add(1, Ordering::Relaxed);
        Ok(Some(WeightBlob { node: p.node, round: p.round, weights }))
    }

    /// Return `n` buffered bytes to `from`'s budget.
    fn credit(&self, from: crate::crypto::NodeId, n: u64) {
        let mut budgets = self.sender_bytes.lock().unwrap();
        if let Some(used) = budgets.get_mut(&from) {
            *used = used.saturating_sub(n);
            if *used == 0 {
                budgets.remove(&from);
            }
        }
    }

    /// Drop partials older than `keep_from_round` (pool GC companion).
    pub fn gc(&self, keep_from_round: u64) {
        for slot in &self.shards {
            let mut shard = slot.lock().unwrap();
            let mut reaped: Vec<(crate::crypto::NodeId, u64)> = Vec::new();
            shard.partials.retain(|(from, _), p| {
                if p.round >= keep_from_round {
                    true
                } else {
                    reaped.push((*from, p.covered));
                    false
                }
            });
            drop(shard);
            for (from, covered) in reaped {
                self.credit(from, covered);
            }
        }
    }

    /// Byte ranges of `(from, digest)`'s declared image not yet covered
    /// by buffered segments, as sorted `[start, end)` pairs. `None` when
    /// no partial exists for that key. This is what lets a receiver that
    /// lost one multicast chunk pull exactly the missing slice from the
    /// original sender — the reply lands in the SAME partial and
    /// completes it.
    pub fn missing_ranges(
        &self,
        from: crate::crypto::NodeId,
        digest: &Digest,
    ) -> Option<Vec<(u32, u32)>> {
        let shard = self.shard(digest);
        let p = shard.partials.get(&(from, *digest))?;
        let mut covered: Vec<(u32, u32)> = p
            .segments
            .iter()
            .map(|(off, seg)| (*off, off + seg.len() as u32))
            .collect();
        covered.sort_unstable();
        let mut missing = Vec::new();
        let mut cursor = 0u32;
        for (start, end) in covered {
            if start > cursor {
                missing.push((cursor, start));
            }
            cursor = cursor.max(end);
        }
        if cursor < p.total_bytes {
            missing.push((cursor, p.total_bytes));
        }
        Some(missing)
    }

    /// Partial blobs currently buffered.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().partials.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes held by partial buffers across all senders (RAM gauge).
    pub fn bytes(&self) -> u64 {
        self.sender_bytes.lock().unwrap().values().sum()
    }

    /// Blobs fully reassembled and digest-verified.
    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    /// Chunks refused by any validation above.
    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn blob(tag: f32, len: usize) -> Vec<f32> {
        (0..len).map(|i| tag + i as f32).collect()
    }

    #[test]
    fn put_get_roundtrip() {
        let p = WeightPool::new(2);
        let w = blob(1.0, 100);
        let d = p.put(0, w.clone());
        assert_eq!(p.get(&d).unwrap().as_slice(), &w[..]);
        assert!(p.contains(&d));
        assert_eq!(p.bytes(), 400);
    }

    #[test]
    fn put_and_get_share_storage_zero_copy() {
        // The commit path's zero-copy contract: the tensor the node keeps,
        // the pool entry, and what aggregation reads are ONE allocation.
        let p = WeightPool::new(2);
        let w = Weights::new(blob(3.0, 64));
        let d = p.put(1, w.clone());
        let got = p.get(&d).unwrap();
        assert!(Weights::ptr_eq(&w, &got), "pool copied the tensor");
        // The digest came from the tensor's cache — same value either way.
        assert_eq!(got.digest(), d);
    }

    #[test]
    fn missing_digest_errors() {
        let p = WeightPool::new(2);
        assert!(p.get(&Digest::zero()).is_err());
    }

    #[test]
    fn get_many_returns_rows_in_request_order() {
        let p = WeightPool::new(2);
        let a = p.put(0, blob(1.0, 8));
        let b = p.put(0, blob(2.0, 8));
        let got = p.get_many(&[b, a, b]).unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].as_slice()[0], 2.0);
        assert_eq!(got[1].as_slice()[0], 1.0);
        assert_eq!(got[2].as_slice()[0], 2.0);
        // Handles share pool storage (no copy on batch fetch either).
        assert!(Weights::ptr_eq(&got[0], &got[2]));
    }

    #[test]
    fn get_many_reports_every_missing_digest_with_context() {
        let p = WeightPool::new(2);
        let present = p.put(0, blob(1.0, 8));
        let ghost = Digest::of_bytes(b"never-inserted");
        let err = p.get_many(&[present, ghost]).unwrap_err().to_string();
        assert!(err.contains("1/2"), "count context missing: {err}");
        assert!(err.contains(&ghost.short()), "missing digest absent: {err}");
        assert!(err.contains(&present.short()), "request context absent: {err}");
    }

    #[test]
    fn gc_gauge_saturates_instead_of_wrapping() {
        let p = WeightPool::new(2);
        p.put(0, blob(1.0, 16));
        p.gc(100); // everything reaped
        assert_eq!(p.bytes(), 0);
        p.gc(200); // nothing left to reap; gauge must stay at zero
        assert_eq!(p.bytes(), 0);
        assert!(p.is_empty());
    }

    #[test]
    fn content_addressing_dedups() {
        let p = WeightPool::new(2);
        let d1 = p.put(0, blob(1.0, 10));
        let d2 = p.put(1, blob(1.0, 10));
        assert_eq!(d1, d2);
        assert_eq!(p.len(), 1);
        assert_eq!(p.bytes(), 40);
    }

    #[test]
    fn gc_enforces_tau_rounds() {
        let p = WeightPool::new(2);
        let d0 = p.put(0, blob(0.0, 10));
        let d1 = p.put(1, blob(1.0, 10));
        let d2 = p.put(2, blob(2.0, 10));
        p.gc(2); // keep rounds >= 1
        assert!(!p.contains(&d0));
        assert!(p.contains(&d1));
        assert!(p.contains(&d2));
        assert_eq!(p.bytes(), 80);
    }

    #[test]
    fn gc_keeps_byte_gauge_consistent_incrementally() {
        // Mixed sizes so a stale gauge would be caught exactly.
        let p = WeightPool::new(2);
        for round in 0..20u64 {
            p.put(round, blob(round as f32, 10 + (round as usize % 3) * 5));
            p.gc(round);
            let expected: u64 = (0..=round)
                .filter(|r| *r + 1 >= round)
                .map(|r| (10 + (r as usize % 3) * 5) as u64 * 4)
                .sum();
            assert_eq!(p.bytes(), expected, "gauge drifted at round {round}");
        }
    }

    #[test]
    fn storage_bounded_regardless_of_rounds() {
        // The §4.3 claim: Mτn storage, independent of T.
        let n = 4;
        let tau = 2u64;
        let p = WeightPool::new(tau as usize);
        for round in 0..200u64 {
            for node in 0..n {
                p.put(round, blob(round as f32 * 10.0 + node as f32, 50));
            }
            p.gc(round);
            assert!(
                p.len() as u64 <= tau * n as u64,
                "round {round}: {} entries > tau*n", p.len()
            );
        }
        assert_eq!(p.bytes(), p.len() as u64 * 200);
        assert!(p.peak_bytes() <= (tau * n as u64 + n as u64) * 200);
    }

    #[test]
    fn reinsert_bumps_round_protects_from_gc() {
        let p = WeightPool::new(2);
        let d = p.put(0, blob(7.0, 10));
        p.put(5, blob(7.0, 10)); // same content at a later round
        p.gc(5);
        assert!(p.contains(&d));
    }

    #[test]
    #[should_panic(expected = "tau")]
    fn tau_one_rejected() {
        WeightPool::new(1);
    }

    #[test]
    fn gc_with_nothing_expired_scans_zero_entries() {
        // The short-circuit satellite: the steady-state gc (every round
        // advance, nothing past the horizon) must not walk entries at
        // all — its cost is pinned to the expired-entry population.
        let p = WeightPool::new(2);
        for i in 0..64u64 {
            p.put(10, blob(i as f32, 4 + i as usize % 7));
        }
        let live = p.len();
        p.gc(10); // horizon keeps round >= 9: nothing expired
        p.gc(11); // keeps round >= 10: still nothing expired
        assert_eq!(p.gc_scanned(), 0, "no-op gc walked entries");
        assert!(p.gc_short_circuits() > 0, "short-circuit never took effect");
        assert_eq!(p.len(), live);

        // Expire everything: now (and only now) entries get scanned —
        // at most one scan per entry per reaping gc, never per no-op gc.
        p.gc(12); // keeps round >= 11: all 64 expire
        assert_eq!(p.len(), 0);
        assert_eq!(p.gc_scanned(), live as u64, "reaping gc cost != expired population");
        let after_reap = p.gc_scanned();
        p.gc(13); // empty pool: free again
        assert_eq!(p.gc_scanned(), after_reap);
        assert_eq!(p.bytes(), 0);
    }

    #[test]
    fn gc_short_circuit_survives_round_bumped_reinserts() {
        // A re-insert bumps an entry's round tag without re-deriving the
        // shard's min_round; the stale-low bound may cost a scan but must
        // never skip a due reap.
        let p = WeightPool::new(2);
        let d_old = p.put(1, blob(1.0, 8));
        let d_new = p.put(1, blob(2.0, 8));
        p.put(9, blob(2.0, 8)); // bump d_new's round to 9
        p.gc(9); // keep round >= 8: d_old must go, d_new must stay
        assert!(!p.contains(&d_old));
        assert!(p.contains(&d_new));
        assert_eq!(p.bytes(), 32);
    }

    #[test]
    fn concurrent_put_get_gc_hammer_keeps_gauges_consistent() {
        // The sharded-pool contract under real contention: 4 writer
        // threads putting round-tagged blobs, readers fetching them, and
        // a gc thread reaping — no lost entries, no gauge drift, no
        // deadlock. Content is disjoint per thread so the expected final
        // population is exact.
        let p = Arc::new(WeightPool::new(2));
        let threads = 4;
        let per_thread = 40usize;
        let pool = crate::util::workers::WorkerPool::new(threads);
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let p = Arc::clone(&p);
                pool.spawn_task(move || {
                    for i in 0..per_thread {
                        let round = (i / 4) as u64;
                        let tag = (t * 1000 + i) as f32;
                        let d = p.put(round, blob(tag, 8 + t));
                        // Read back through the shared lock immediately; a
                        // faster thread's gc may already have reaped an
                        // old-round entry, so presence is not guaranteed —
                        // but a present entry must be intact.
                        if let Ok(got) = p.get(&d) {
                            assert_eq!(got.as_slice()[0], tag);
                        }
                        if i % 5 == 0 {
                            p.gc(round);
                        }
                        let _ = p.get_many(&[d]);
                        let _ = p.contains(&d);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join(); // re-panics if a hammer job panicked
        }
        // Final horizon: keep rounds >= last_round - 1.
        let last_round = ((per_thread - 1) / 4) as u64;
        p.gc(last_round);
        let expect_rounds = [last_round - 1, last_round];
        let expected: usize = (0..threads)
            .map(|t| {
                (0..per_thread)
                    .filter(|i| expect_rounds.contains(&((i / 4) as u64)))
                    .map(|i| (t, i))
                    .count()
            })
            .sum();
        assert_eq!(p.len(), expected, "entries lost or leaked under contention");
        let expected_bytes: u64 = (0..threads)
            .map(|t| {
                (0..per_thread)
                    .filter(|i| expect_rounds.contains(&((i / 4) as u64)))
                    .map(|_| ((8 + t) * 4) as u64)
                    .sum::<u64>()
            })
            .sum();
        assert_eq!(p.bytes(), expected_bytes, "byte gauge drifted under contention");
        assert!(p.peak_bytes() >= p.bytes());
    }

    // ---------------- chunk reassembly ----------------

    /// Split a tensor's wire image into `chunk` -byte chunks (mirrors the
    /// sender in `defl::tx::multicast_blob`).
    fn chunks_of(w: &Weights, node: u32, round: u64, chunk: usize) -> Vec<BlobChunk> {
        let bytes = w.as_bytes();
        let digest = w.digest();
        let mut out = Vec::new();
        let mut off = 0usize;
        while off < bytes.len() {
            let end = (off + chunk).min(bytes.len());
            out.push(BlobChunk {
                node,
                round,
                digest,
                total_bytes: bytes.len() as u32,
                offset: off as u32,
                payload: bytes[off..end].to_vec(),
            });
            off = end;
        }
        out
    }

    #[test]
    fn chunks_reassemble_to_the_identical_tensor() {
        let w = Weights::new(blob(4.0, 100)); // 400 bytes
        let asm = ChunkAssembler::new(1 << 20);
        let mut got = None;
        for c in chunks_of(&w, 7, 3, 96) {
            got = asm.accept(0, c).unwrap();
        }
        let back = got.expect("last chunk completes");
        assert_eq!(back.node, 7);
        assert_eq!(back.round, 3);
        assert_eq!(back.weights.as_slice(), w.as_slice());
        assert_eq!(back.digest(), w.digest());
        assert_eq!(asm.completed(), 1);
        assert_eq!(asm.bytes(), 0);
        assert!(asm.is_empty());
    }

    #[test]
    fn duplicate_and_reordered_chunks_are_idempotent() {
        let w = Weights::new(blob(1.0, 64));
        let asm = ChunkAssembler::new(1 << 20);
        let mut cs = chunks_of(&w, 0, 1, 60);
        cs.reverse();
        assert!(asm.accept(0, cs[0].clone()).unwrap().is_none());
        assert!(asm.accept(0, cs[0].clone()).unwrap().is_none()); // dup
        let done = asm.accept(0, cs[1].clone()).unwrap().expect("complete");
        assert_eq!(done.weights.as_slice(), w.as_slice());
    }

    #[test]
    fn adversarial_chunks_rejected() {
        let w = Weights::new(blob(2.0, 32)); // 128 bytes
        let asm = ChunkAssembler::new(1 << 20);
        let cs = chunks_of(&w, 1, 1, 64);
        // Out-of-range chunk.
        let mut bad = cs[0].clone();
        bad.offset = 100;
        assert!(asm.accept(0, bad).is_err());
        // Empty payload.
        let mut bad = cs[0].clone();
        bad.payload.clear();
        assert!(asm.accept(0, bad).is_err());
        // Conflicting total after the first chunk landed.
        assert!(asm.accept(0, cs[0].clone()).unwrap().is_none());
        let mut bad = cs[1].clone();
        bad.total_bytes = 64;
        bad.offset = 0;
        assert!(asm.accept(0, bad).is_err());
        assert!(asm.rejected() >= 3);
    }

    #[test]
    fn corrupted_payload_fails_the_digest_check() {
        let w = Weights::new(blob(5.0, 40));
        let asm = ChunkAssembler::new(1 << 20);
        let mut cs = chunks_of(&w, 2, 4, 80);
        cs[1].payload[0] ^= 0xff;
        assert!(asm.accept(0, cs[0].clone()).unwrap().is_none());
        let err = asm.accept(0, cs[1].clone()).unwrap_err().to_string();
        assert!(err.contains("does not hash"), "{err}");
        // The poisoned partial is gone; a clean retransmit succeeds.
        let mut got = None;
        for c in chunks_of(&w, 2, 4, 80) {
            got = asm.accept(0, c).unwrap();
        }
        assert_eq!(got.expect("clean retry").weights.as_slice(), w.as_slice());
    }

    #[test]
    fn byzantine_injection_cannot_suppress_an_honest_sender() {
        // A Byzantine peer (transport id 9) injects a junk chunk for the
        // honest blob's digest before the honest sender's own chunks
        // finish. Partials are keyed by (sender, digest), so the junk
        // builds a doomed partial of its own and the honest stream
        // reassembles untouched.
        let w = Weights::new(blob(6.0, 64)); // 256-byte image
        let honest = chunks_of(&w, 4, 2, 100);
        let asm = ChunkAssembler::new(1 << 20);
        assert!(asm.accept(4, honest[0].clone()).unwrap().is_none());
        let mut forged = honest[1].clone();
        for b in forged.payload.iter_mut() {
            *b = 0xaa;
        }
        assert!(asm.accept(9, forged).unwrap().is_none());
        // Honest chunks still land in the honest partial and complete.
        assert!(asm.accept(4, honest[1].clone()).unwrap().is_none());
        let done = asm.accept(4, honest[2].clone()).unwrap().expect("honest blob completes");
        assert_eq!(done.weights.as_slice(), w.as_slice());
        // The forged partial lingers (until GC) but harms nothing.
        assert_eq!(asm.len(), 1);
        assert_eq!(asm.completed(), 1);
    }

    #[test]
    fn per_sender_budget_isolates_flooders_and_horizon_bounds_rounds() {
        let asm = ChunkAssembler::new(300);
        asm.set_round_horizon(5);
        // Round tags beyond the horizon are refused outright — junk can
        // no longer park where gc() never reaps it.
        let w = Weights::new(blob(1.0, 64));
        let mut parked = chunks_of(&w, 0, u64::MAX, 100)[0].clone();
        assert!(asm.accept(7, parked.clone()).is_err());
        parked.round = 4;
        assert!(asm.accept(7, parked).unwrap().is_none());
        // Sender 7 exhausts ITS 300-byte budget...
        let junk = Weights::new(blob(2.0, 64));
        assert!(asm.accept(7, chunks_of(&junk, 0, 4, 100)[0].clone()).unwrap().is_none());
        assert!(asm.accept(7, chunks_of(&junk, 0, 4, 100)[1].clone()).unwrap().is_none());
        assert!(asm.accept(7, chunks_of(&junk, 0, 4, 100)[2].clone()).is_err());
        // ...while the honest sender 4 is completely unaffected.
        let honest = Weights::new(blob(3.0, 64));
        let mut done = None;
        for c in chunks_of(&honest, 4, 4, 100) {
            done = asm.accept(4, c).unwrap();
        }
        assert_eq!(done.expect("honest blob").weights.as_slice(), honest.as_slice());
    }

    #[test]
    fn pool_entry_exposes_round_and_shares_storage() {
        let p = WeightPool::new(2);
        let w = Weights::new(blob(9.0, 16));
        let d = p.put(3, w.clone());
        let (round, got) = p.entry(&d).expect("present");
        assert_eq!(round, 3);
        assert!(Weights::ptr_eq(&w, &got), "entry copied the tensor");
        assert!(p.entry(&Digest::zero()).is_none());
    }

    #[test]
    fn missing_ranges_track_partial_coverage() {
        let w = Weights::new(blob(1.0, 64)); // 256-byte image, 4x64 chunks
        let asm = ChunkAssembler::new(1 << 20);
        let cs = chunks_of(&w, 2, 1, 64);
        let d = w.digest();
        assert!(asm.missing_ranges(2, &d).is_none(), "no partial yet");
        asm.accept(2, cs[0].clone()).unwrap();
        asm.accept(2, cs[2].clone()).unwrap();
        assert_eq!(
            asm.missing_ranges(2, &d).unwrap(),
            vec![(64, 128), (192, 256)],
            "holes after chunks 0 and 2 landed"
        );
        // Another sender's partial is tracked independently.
        assert!(asm.missing_ranges(7, &d).is_none());
        asm.accept(2, cs[1].clone()).unwrap();
        assert_eq!(asm.missing_ranges(2, &d).unwrap(), vec![(192, 256)]);
        // Completion removes the partial (and with it the ranges).
        assert!(asm.accept(2, cs[3].clone()).unwrap().is_some());
        assert!(asm.missing_ranges(2, &d).is_none());
    }

    #[test]
    fn assembler_gc_reaps_stale_partials_and_enforces_cap() {
        let w_old = Weights::new(blob(1.0, 50)); // 200-byte image
        let w_new = Weights::new(blob(2.0, 50));
        let asm = ChunkAssembler::new(250);
        // A claimed image the cap could never admit is refused outright —
        // a tiny frame cannot reserve a huge buffer.
        let mut huge = chunks_of(&w_old, 0, 1, 100)[0].clone();
        huge.total_bytes = 1 << 20;
        assert!(asm.accept(0, huge).is_err());
        // Buffered bytes are charged per RECEIVED payload, not per claim.
        assert!(asm.accept(0, chunks_of(&w_old, 0, 1, 100)[0].clone()).unwrap().is_none());
        assert!(asm.accept(0, chunks_of(&w_new, 0, 9, 100)[0].clone()).unwrap().is_none());
        assert_eq!(asm.bytes(), 200);
        // The next segment would push the buffers past the 250-byte cap.
        assert!(asm.accept(0, chunks_of(&w_new, 0, 9, 100)[1].clone()).is_err());
        // GC reaps the stale round-1 partial, freeing room to finish.
        asm.gc(8);
        assert_eq!(asm.len(), 1);
        assert_eq!(asm.bytes(), 100);
        let done = asm.accept(0, chunks_of(&w_new, 0, 9, 100)[1].clone()).unwrap();
        assert_eq!(done.expect("complete").weights.as_slice(), w_new.as_slice());
    }

    #[test]
    fn concurrent_reassembly_from_many_senders() {
        // Sharded-assembler smoke: 4 sender threads interleave chunk
        // streams for distinct blobs; every blob must complete exactly
        // once and all budgets must return to zero.
        let asm = Arc::new(ChunkAssembler::new(1 << 20));
        let pool = crate::util::workers::WorkerPool::new(4);
        let handles: Vec<_> = (0..4u32)
            .map(|t| {
                let asm = Arc::clone(&asm);
                pool.spawn_task(move || {
                    let mut done = 0u64;
                    for b in 0..8u32 {
                        let w = Weights::new(blob((t * 100 + b) as f32, 32 + b as usize));
                        for c in chunks_of(&w, t, 1, 40) {
                            if let Some(blob) = asm.accept(t, c).unwrap() {
                                assert_eq!(blob.weights.as_slice(), w.as_slice());
                                done += 1;
                            }
                        }
                    }
                    done
                })
            })
            .collect();
        let total: u64 = handles.into_iter().map(|h| h.join()).sum();
        assert_eq!(total, 32);
        assert_eq!(asm.completed(), 32);
        assert_eq!(asm.bytes(), 0);
        assert!(asm.is_empty());
    }
}
