//! Decoupled storage layer (DeFL §3.4): a digest-addressed weight pool.
//!
//! Consensus transactions carry only `Digest`s; the blobs themselves live
//! here. The pool retains weights for at most τ ≥ 2 training rounds
//! (current + last, §4.3), so storage is Mτn regardless of T — the 100×
//! win over chain-based baselines in Figure 2. `gc(round)` drops
//! everything older than `round − τ + 1`.
//!
//! Entries are [`Weights`] handles: inserting a tensor the caller also
//! holds (trainer output, decoded blob) shares the allocation instead of
//! copying it, the content digest is taken from the tensor's cache (one
//! SHA-256 per tensor per process, not per layer), and `get` hands back
//! a cheap clone the aggregation path can keep across pool mutations.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::crypto::Digest;
use crate::weights::Weights;

/// A stored weight blob, tagged with the round it belongs to.
#[derive(Debug, Clone)]
struct Entry {
    round: u64,
    weights: Weights,
}

/// Content-addressed, round-tagged weight pool with τ-round retention.
#[derive(Debug)]
pub struct WeightPool {
    tau: u64,
    entries: BTreeMap<Digest, Entry>,
    /// Running byte gauge (4 bytes per f32 element), maintained
    /// incrementally by `put`/`gc`.
    bytes: u64,
    /// Peak bytes ever resident (RAM model input).
    peak_bytes: u64,
}

impl WeightPool {
    pub fn new(tau: usize) -> WeightPool {
        assert!(tau >= 2, "tau must cover current + last round");
        WeightPool {
            tau: tau as u64,
            entries: BTreeMap::new(),
            bytes: 0,
            peak_bytes: 0,
        }
    }

    /// Insert a blob under its (cached) content digest. Returns the digest.
    /// Re-inserting identical content is a no-op (content addressing).
    pub fn put(&mut self, round: u64, weights: impl Into<Weights>) -> Digest {
        let weights = weights.into();
        let digest = weights.digest();
        if let Some(prev) = self.entries.get_mut(&digest) {
            // Same content seen again (e.g. re-broadcast): keep the newest
            // round tag so GC doesn't reap a still-referenced blob.
            prev.round = prev.round.max(round);
            return digest;
        }
        self.bytes += (weights.len() * 4) as u64;
        self.peak_bytes = self.peak_bytes.max(self.bytes);
        self.entries.insert(digest, Entry { round, weights });
        digest
    }

    /// Fetch a blob: a cheap handle clone that stays valid across later
    /// pool mutations (so aggregation never copies rows out).
    pub fn get(&self, digest: &Digest) -> Result<Weights> {
        match self.entries.get(digest) {
            Some(e) => Ok(e.weights.clone()),
            None => bail!("mempool: {} not present", digest.short()),
        }
    }

    /// Batch lookup for an aggregation row set. All-or-nothing: on any
    /// miss the error names every missing digest AND the full requested
    /// list, so a lost blob is diagnosable in one log line instead of n
    /// separate "not present" errors.
    pub fn get_many(&self, digests: &[Digest]) -> Result<Vec<Weights>> {
        let mut out = Vec::with_capacity(digests.len());
        let mut missing: Vec<String> = Vec::new();
        for d in digests {
            match self.entries.get(d) {
                Some(e) => out.push(e.weights.clone()),
                None => missing.push(d.short()),
            }
        }
        if !missing.is_empty() {
            let wanted: Vec<String> = digests.iter().map(|d| d.short()).collect();
            bail!(
                "mempool: {}/{} digests missing [{}] of requested [{}]",
                missing.len(),
                digests.len(),
                missing.join(", "),
                wanted.join(", ")
            );
        }
        Ok(out)
    }

    pub fn contains(&self, digest: &Digest) -> bool {
        self.entries.contains_key(digest)
    }

    /// Drop all blobs older than `current_round − τ + 1`. The byte gauge
    /// is maintained incrementally (subtract what was reaped) instead of
    /// re-summing every surviving entry; the subtraction saturates so an
    /// accounting bug can never wrap the gauge to ~u64::MAX and poison
    /// every storage metric downstream.
    pub fn gc(&mut self, current_round: u64) {
        let keep_from = current_round.saturating_sub(self.tau - 1);
        let mut reaped = 0u64;
        self.entries.retain(|_, e| {
            if e.round >= keep_from {
                true
            } else {
                reaped += (e.weights.len() * 4) as u64;
                false
            }
        });
        self.bytes = self.bytes.saturating_sub(reaped);
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(tag: f32, len: usize) -> Vec<f32> {
        (0..len).map(|i| tag + i as f32).collect()
    }

    #[test]
    fn put_get_roundtrip() {
        let mut p = WeightPool::new(2);
        let w = blob(1.0, 100);
        let d = p.put(0, w.clone());
        assert_eq!(p.get(&d).unwrap().as_slice(), &w[..]);
        assert!(p.contains(&d));
        assert_eq!(p.bytes(), 400);
    }

    #[test]
    fn put_and_get_share_storage_zero_copy() {
        // The commit path's zero-copy contract: the tensor the node keeps,
        // the pool entry, and what aggregation reads are ONE allocation.
        let mut p = WeightPool::new(2);
        let w = Weights::new(blob(3.0, 64));
        let d = p.put(1, w.clone());
        let got = p.get(&d).unwrap();
        assert!(Weights::ptr_eq(&w, &got), "pool copied the tensor");
        // The digest came from the tensor's cache — same value either way.
        assert_eq!(got.digest(), d);
    }

    #[test]
    fn missing_digest_errors() {
        let p = WeightPool::new(2);
        assert!(p.get(&Digest::zero()).is_err());
    }

    #[test]
    fn get_many_returns_rows_in_request_order() {
        let mut p = WeightPool::new(2);
        let a = p.put(0, blob(1.0, 8));
        let b = p.put(0, blob(2.0, 8));
        let got = p.get_many(&[b, a, b]).unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].as_slice()[0], 2.0);
        assert_eq!(got[1].as_slice()[0], 1.0);
        assert_eq!(got[2].as_slice()[0], 2.0);
        // Handles share pool storage (no copy on batch fetch either).
        assert!(Weights::ptr_eq(&got[0], &got[2]));
    }

    #[test]
    fn get_many_reports_every_missing_digest_with_context() {
        let mut p = WeightPool::new(2);
        let present = p.put(0, blob(1.0, 8));
        let ghost = Digest::of_bytes(b"never-inserted");
        let err = p.get_many(&[present, ghost]).unwrap_err().to_string();
        assert!(err.contains("1/2"), "count context missing: {err}");
        assert!(err.contains(&ghost.short()), "missing digest absent: {err}");
        assert!(err.contains(&present.short()), "request context absent: {err}");
    }

    #[test]
    fn gc_gauge_saturates_instead_of_wrapping() {
        let mut p = WeightPool::new(2);
        p.put(0, blob(1.0, 16));
        p.gc(100); // everything reaped
        assert_eq!(p.bytes(), 0);
        p.gc(200); // nothing left to reap; gauge must stay at zero
        assert_eq!(p.bytes(), 0);
        assert!(p.is_empty());
    }

    #[test]
    fn content_addressing_dedups() {
        let mut p = WeightPool::new(2);
        let d1 = p.put(0, blob(1.0, 10));
        let d2 = p.put(1, blob(1.0, 10));
        assert_eq!(d1, d2);
        assert_eq!(p.len(), 1);
        assert_eq!(p.bytes(), 40);
    }

    #[test]
    fn gc_enforces_tau_rounds() {
        let mut p = WeightPool::new(2);
        let d0 = p.put(0, blob(0.0, 10));
        let d1 = p.put(1, blob(1.0, 10));
        let d2 = p.put(2, blob(2.0, 10));
        p.gc(2); // keep rounds >= 1
        assert!(!p.contains(&d0));
        assert!(p.contains(&d1));
        assert!(p.contains(&d2));
        assert_eq!(p.bytes(), 80);
    }

    #[test]
    fn gc_keeps_byte_gauge_consistent_incrementally() {
        // Mixed sizes so a stale gauge would be caught exactly.
        let mut p = WeightPool::new(2);
        for round in 0..20u64 {
            p.put(round, blob(round as f32, 10 + (round as usize % 3) * 5));
            p.gc(round);
            let expected: u64 = (0..=round)
                .filter(|r| *r + 1 >= round)
                .map(|r| (10 + (r as usize % 3) * 5) as u64 * 4)
                .sum();
            assert_eq!(p.bytes(), expected, "gauge drifted at round {round}");
        }
    }

    #[test]
    fn storage_bounded_regardless_of_rounds() {
        // The §4.3 claim: Mτn storage, independent of T.
        let n = 4;
        let tau = 2u64;
        let mut p = WeightPool::new(tau as usize);
        for round in 0..200u64 {
            for node in 0..n {
                p.put(round, blob(round as f32 * 10.0 + node as f32, 50));
            }
            p.gc(round);
            assert!(
                p.len() as u64 <= tau * n as u64,
                "round {round}: {} entries > tau*n", p.len()
            );
        }
        assert_eq!(p.bytes(), p.len() as u64 * 200);
        assert!(p.peak_bytes() <= (tau * n as u64 + n as u64) * 200);
    }

    #[test]
    fn reinsert_bumps_round_protects_from_gc() {
        let mut p = WeightPool::new(2);
        let d = p.put(0, blob(7.0, 10));
        p.put(5, blob(7.0, 10)); // same content at a later round
        p.gc(5);
        assert!(p.contains(&d));
    }

    #[test]
    #[should_panic(expected = "tau")]
    fn tau_one_rejected() {
        WeightPool::new(1);
    }
}
