//! Digests, node signatures, and quorum certificates.
//!
//! * `Digest` — SHA-256 content address. UPD transactions carry the digest
//!   of the weight blob instead of the blob itself (DeFL §3.4 decoupling
//!   of storage and consensus); replicas verify retrieved blobs against it.
//! * `Signer`/`KeyRegistry` — per-node HMAC-SHA256 authenticators. The
//!   paper's deployment would use asymmetric signatures; in this simulation
//!   a trusted symmetric key registry stands in (DESIGN.md substitution
//!   table), with the signature size configurable so network accounting
//!   still matches a 64-byte ed25519-style scheme.
//! * `QuorumCert` — a set of `(node, signature)` votes over one message
//!   digest; `verify` checks every vote and the quorum size.

use hmac::{Hmac, Mac};
use sha2::{Digest as _, Sha256};

use crate::util::codec::{decode_list, encode_list, Cursor, Decode, Encode};
use anyhow::{bail, Result};

/// Node identifier (index into the experiment's node set).
pub type NodeId = u32;

/// Wire size we account for one signature (ed25519-equivalent).
pub const SIG_WIRE_BYTES: usize = 64;

/// SHA-256 content address.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Digest(pub [u8; 32]);

impl Digest {
    pub fn of_bytes(bytes: &[u8]) -> Digest {
        let mut h = Sha256::new();
        h.update(bytes);
        Digest(h.finalize().into())
    }

    /// Digest of a flat f32 weight vector (LE bytes) — the content address
    /// every UPD transaction carries.
    pub fn of_weights(w: &[f32]) -> Digest {
        let mut h = Sha256::new();
        for x in w {
            h.update(x.to_le_bytes());
        }
        Digest(h.finalize().into())
    }

    pub fn zero() -> Digest {
        Digest([0; 32])
    }

    pub fn hex(&self) -> String {
        self.0.iter().map(|b| format!("{b:02x}")).collect()
    }

    pub fn short(&self) -> String {
        self.hex()[..8].to_string()
    }
}

impl std::fmt::Debug for Digest {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Digest({})", self.short())
    }
}

impl Encode for Digest {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.0);
    }
    fn encoded_len(&self) -> usize {
        32
    }
}

impl Decode for Digest {
    fn decode(cur: &mut Cursor<'_>) -> Result<Self> {
        Ok(Digest(<[u8; 32]>::decode(cur)?))
    }
}

/// A node's authenticator over a message digest.
#[derive(Clone, PartialEq, Eq)]
pub struct Signature {
    pub node: NodeId,
    pub mac: [u8; 32],
}

impl std::fmt::Debug for Signature {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Sig(n{}, {:02x}{:02x}..)", self.node, self.mac[0], self.mac[1])
    }
}

impl Encode for Signature {
    fn encode(&self, out: &mut Vec<u8>) {
        self.node.encode(out);
        out.extend_from_slice(&self.mac);
        // Pad to the wire size of an asymmetric signature so byte meters
        // match a deployable scheme.
        out.extend_from_slice(&[0u8; SIG_WIRE_BYTES - 32 - 4]);
    }
    fn encoded_len(&self) -> usize {
        SIG_WIRE_BYTES
    }
}

impl Decode for Signature {
    fn decode(cur: &mut Cursor<'_>) -> Result<Self> {
        let node = NodeId::decode(cur)?;
        let mac = <[u8; 32]>::decode(cur)?;
        let _pad = cur.take(SIG_WIRE_BYTES - 32 - 4)?;
        Ok(Signature { node, mac })
    }
}

type HmacSha256 = Hmac<Sha256>;

/// Per-node signing key.
#[derive(Clone)]
pub struct Signer {
    pub node: NodeId,
    key: [u8; 32],
}

impl Signer {
    pub fn sign(&self, msg: &Digest) -> Signature {
        let mut mac = HmacSha256::new_from_slice(&self.key).expect("hmac key");
        mac.update(&msg.0);
        Signature {
            node: self.node,
            mac: mac.finalize().into_bytes().into(),
        }
    }
}

/// Trusted registry of node keys (the simulation's PKI stand-in).
#[derive(Clone)]
pub struct KeyRegistry {
    keys: Vec<[u8; 32]>,
}

impl KeyRegistry {
    /// Derive n node keys from a cluster seed.
    pub fn new(n: usize, cluster_seed: u64) -> KeyRegistry {
        let keys = (0..n)
            .map(|i| {
                let mut h = Sha256::new();
                h.update(b"defl-node-key");
                h.update(cluster_seed.to_le_bytes());
                h.update((i as u64).to_le_bytes());
                h.finalize().into()
            })
            .collect();
        KeyRegistry { keys }
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn signer(&self, node: NodeId) -> Signer {
        Signer {
            node,
            key: self.keys[node as usize],
        }
    }

    pub fn verify(&self, msg: &Digest, sig: &Signature) -> bool {
        let Some(key) = self.keys.get(sig.node as usize) else {
            return false;
        };
        let mut mac = HmacSha256::new_from_slice(key).expect("hmac key");
        mac.update(&msg.0);
        mac.verify_slice(&sig.mac).is_ok()
    }
}

/// Per-message envelope carried by every wire frame (weights, consensus,
/// fetch, sync, control): the sender's signature over
/// `(class, sender, payload digest)`. Binding the traffic class and the
/// claimed sender into the signed digest means a frame cannot be replayed
/// as a different class or re-attributed to another node — a validly
/// signed frame re-sent with a different `sender` field fails both the
/// `sig.node == sender` check and the binding digest.
#[derive(Clone, PartialEq)]
pub struct SignedFrame {
    pub sender: NodeId,
    /// Transport traffic-class byte (see `net::transport::class_wire_byte`);
    /// part
    /// of the signed binding so frames cannot cross classes.
    pub class: u8,
    pub sig: Signature,
    pub payload: Vec<u8>,
}

impl std::fmt::Debug for SignedFrame {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "SignedFrame(n{}, class {}, {} B, {:?})",
            self.sender,
            self.class,
            self.payload.len(),
            self.sig
        )
    }
}

impl SignedFrame {
    /// The digest a frame signature covers: `H(class ‖ sender ‖ H(payload))`.
    /// Hashing the payload digest (not the payload) keeps the binding
    /// computation O(payload) once and lets transports that already know
    /// the payload digest skip the re-hash.
    pub fn binding(sender: NodeId, class: u8, payload: &[u8]) -> Digest {
        let pd = Digest::of_bytes(payload);
        let mut buf = [0u8; 1 + 4 + 32];
        buf[0] = class;
        buf[1..5].copy_from_slice(&sender.to_le_bytes());
        buf[5..].copy_from_slice(&pd.0);
        Digest::of_bytes(&buf)
    }

    /// Sign `payload` as `signer`'s node for the given traffic class.
    pub fn seal(signer: &Signer, class: u8, payload: Vec<u8>) -> SignedFrame {
        let sig = signer.sign(&Self::binding(signer.node, class, &payload));
        SignedFrame { sender: signer.node, class, sig, payload }
    }

    /// Verify the envelope against the registry: the signature must be by
    /// the claimed sender's key AND name the sender (so a validly-signed
    /// frame cannot be replayed under another node id).
    pub fn verify(&self, registry: &KeyRegistry) -> bool {
        self.sig.node == self.sender
            && registry.verify(&Self::binding(self.sender, self.class, &self.payload), &self.sig)
    }
}

impl Encode for SignedFrame {
    fn encode(&self, out: &mut Vec<u8>) {
        self.sender.encode(out);
        self.class.encode(out);
        self.sig.encode(out);
        self.payload.encode(out);
    }
    fn encoded_len(&self) -> usize {
        4 + 1 + SIG_WIRE_BYTES + 4 + self.payload.len()
    }
}

impl Decode for SignedFrame {
    fn decode(cur: &mut Cursor<'_>) -> Result<Self> {
        Ok(SignedFrame {
            sender: NodeId::decode(cur)?,
            class: u8::decode(cur)?,
            sig: Signature::decode(cur)?,
            payload: Vec::<u8>::decode(cur)?,
        })
    }
}

/// Batch-verify a queue of `(sender, class, payload)` frames against
/// their signatures, off the caller's hot path: above a small burst the
/// per-frame HMAC checks fan out over the persistent worker pool
/// ([`crate::util::workers`]) in one scoped task set; tiny bursts verify
/// inline (no queue round-trip). Returns one verdict per frame, in order.
pub fn verify_frames(registry: &KeyRegistry, frames: &[SignedFrame]) -> Vec<bool> {
    /// Below this many frames the pool hand-off costs more than the MACs.
    const POOL_BATCH_MIN: usize = 8;
    let mut ok = vec![false; frames.len()];
    if frames.is_empty() {
        return ok;
    }
    let verify_chunk = |start: usize, out: &mut [bool]| {
        for (i, v) in out.iter_mut().enumerate() {
            *v = frames[start + i].verify(registry);
        }
    };
    if frames.len() >= POOL_BATCH_MIN {
        let pool = crate::util::workers::global();
        crate::util::workers::for_each_chunk_mut(pool, &mut ok, pool.workers(), verify_chunk);
    } else {
        verify_chunk(0, &mut ok);
    }
    ok
}

/// Quorum certificate: ≥ quorum distinct-node signatures over one digest.
#[derive(Clone, Debug, PartialEq)]
pub struct QuorumCert {
    pub msg: Digest,
    pub sigs: Vec<Signature>,
}

impl QuorumCert {
    pub fn new(msg: Digest) -> QuorumCert {
        QuorumCert { msg, sigs: Vec::new() }
    }

    /// Add a vote if the node hasn't voted yet. Returns the vote count.
    pub fn add(&mut self, sig: Signature) -> usize {
        if !self.sigs.iter().any(|s| s.node == sig.node) {
            self.sigs.push(sig);
        }
        self.sigs.len()
    }

    pub fn len(&self) -> usize {
        self.sigs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sigs.is_empty()
    }

    /// Check quorum size, distinctness, and every signature.
    pub fn verify(&self, registry: &KeyRegistry, quorum: usize) -> Result<()> {
        if self.sigs.len() < quorum {
            bail!("qc: {} sigs < quorum {}", self.sigs.len(), quorum);
        }
        let mut seen = std::collections::HashSet::new();
        for sig in &self.sigs {
            if !seen.insert(sig.node) {
                bail!("qc: duplicate vote from node {}", sig.node);
            }
            if !registry.verify(&self.msg, sig) {
                bail!("qc: bad signature from node {}", sig.node);
            }
        }
        Ok(())
    }
}

impl Encode for QuorumCert {
    fn encode(&self, out: &mut Vec<u8>) {
        self.msg.encode(out);
        encode_list(&self.sigs, out);
    }
    fn encoded_len(&self) -> usize {
        32 + 4 + self.sigs.len() * SIG_WIRE_BYTES
    }
}

impl Decode for QuorumCert {
    fn decode(cur: &mut Cursor<'_>) -> Result<Self> {
        Ok(QuorumCert {
            msg: Digest::decode(cur)?,
            sigs: decode_list(cur)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digest_is_stable_and_sensitive() {
        let a = Digest::of_weights(&[1.0, 2.0, 3.0]);
        let b = Digest::of_weights(&[1.0, 2.0, 3.0]);
        let c = Digest::of_weights(&[1.0, 2.0, 3.0001]);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.hex().len(), 64);
    }

    #[test]
    fn weights_digest_matches_byte_digest() {
        let w = [0.5f32, -1.25];
        let mut bytes = Vec::new();
        for x in &w {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        assert_eq!(Digest::of_weights(&w), Digest::of_bytes(&bytes));
    }

    #[test]
    fn sign_verify_roundtrip() {
        let reg = KeyRegistry::new(4, 42);
        let msg = Digest::of_bytes(b"hello");
        let sig = reg.signer(2).sign(&msg);
        assert!(reg.verify(&msg, &sig));
        assert!(!reg.verify(&Digest::of_bytes(b"other"), &sig));
    }

    #[test]
    fn forged_node_rejected() {
        let reg = KeyRegistry::new(4, 42);
        let msg = Digest::of_bytes(b"m");
        let mut sig = reg.signer(1).sign(&msg);
        sig.node = 2; // claim to be node 2 with node 1's mac
        assert!(!reg.verify(&msg, &sig));
        sig.node = 99; // out of range
        assert!(!reg.verify(&msg, &sig));
    }

    #[test]
    fn qc_quorum_enforced() {
        let reg = KeyRegistry::new(4, 7);
        let msg = Digest::of_bytes(b"view-1");
        let mut qc = QuorumCert::new(msg);
        for n in 0..3u32 {
            qc.add(reg.signer(n).sign(&msg));
        }
        assert!(qc.verify(&reg, 3).is_ok());
        assert!(qc.verify(&reg, 4).is_err());
    }

    #[test]
    fn qc_duplicate_votes_ignored_on_add() {
        let reg = KeyRegistry::new(4, 7);
        let msg = Digest::of_bytes(b"v");
        let mut qc = QuorumCert::new(msg);
        let s = reg.signer(0).sign(&msg);
        assert_eq!(qc.add(s.clone()), 1);
        assert_eq!(qc.add(s), 1);
    }

    #[test]
    fn qc_bad_sig_rejected() {
        let reg = KeyRegistry::new(4, 7);
        let msg = Digest::of_bytes(b"v");
        let mut qc = QuorumCert::new(msg);
        qc.add(reg.signer(0).sign(&msg));
        let mut bad = reg.signer(1).sign(&msg);
        bad.mac[0] ^= 0xff;
        qc.sigs.push(bad);
        assert!(qc.verify(&reg, 2).is_err());
    }

    #[test]
    fn signed_frame_seals_and_verifies() {
        let reg = KeyRegistry::new(4, 9);
        let f = SignedFrame::seal(&reg.signer(1), 2, b"payload bytes".to_vec());
        assert!(f.verify(&reg));
        // Codec roundtrip preserves validity and every field.
        let bytes = f.to_bytes();
        assert_eq!(bytes.len(), f.encoded_len());
        let back = SignedFrame::from_bytes(&bytes).unwrap();
        assert_eq!(back, f);
        assert!(back.verify(&reg));
    }

    #[test]
    fn signed_frame_rejects_tampering() {
        let reg = KeyRegistry::new(4, 9);
        let f = SignedFrame::seal(&reg.signer(1), 2, b"payload".to_vec());

        // Flipped signature byte.
        let mut bad = f.clone();
        bad.sig.mac[7] ^= 0x01;
        assert!(!bad.verify(&reg));

        // Flipped payload byte.
        let mut bad = f.clone();
        bad.payload[0] ^= 0xff;
        assert!(!bad.verify(&reg));

        // Re-classed frame (same payload, different traffic class).
        let mut bad = f.clone();
        bad.class = 0;
        assert!(!bad.verify(&reg));

        // Wrong-sender replay of a validly-signed frame: both the plain
        // re-attribution and the matching-sig-node variant must fail.
        let mut replay = f.clone();
        replay.sender = 3;
        assert!(!replay.verify(&reg));
        replay.sig.node = 3;
        assert!(!replay.verify(&reg));

        // Unknown sender outside the registry.
        let mut bad = f.clone();
        bad.sender = 99;
        bad.sig.node = 99;
        assert!(!bad.verify(&reg));
    }

    #[test]
    fn signed_frame_truncations_rejected_by_codec() {
        let reg = KeyRegistry::new(2, 5);
        let f = SignedFrame::seal(&reg.signer(0), 1, vec![42u8; 17]);
        let full = f.to_bytes();
        // Every truncation — including cuts inside the signature — must
        // error cleanly, never panic or yield a frame.
        for cut in 0..full.len() {
            assert!(SignedFrame::from_bytes(&full[..cut]).is_err(), "cut {cut} accepted");
        }
        let mut over = full.clone();
        over.push(0);
        assert!(SignedFrame::from_bytes(&over).is_err());
    }

    #[test]
    fn verify_frames_batches_match_singles() {
        let reg = KeyRegistry::new(6, 11);
        // Mix valid, forged-mac, and wrong-sender frames across a batch
        // large enough to take the pooled path.
        let mut frames: Vec<SignedFrame> = (0..24u32)
            .map(|i| SignedFrame::seal(&reg.signer(i % 6), (i % 3) as u8, vec![i as u8; 9]))
            .collect();
        frames[3].sig.mac[0] ^= 1;
        frames[10].sender = (frames[10].sender + 1) % 6;
        frames[17].payload.push(0xee);
        let batch = verify_frames(&reg, &frames);
        for (i, f) in frames.iter().enumerate() {
            assert_eq!(batch[i], f.verify(&reg), "frame {i}");
        }
        assert!(!batch[3] && !batch[10] && !batch[17]);
        assert!(batch[0] && batch[1]);
        // Small batches take the inline path; verdicts must be identical.
        let small = verify_frames(&reg, &frames[..4]);
        assert_eq!(small, batch[..4]);
        assert!(verify_frames(&reg, &[]).is_empty());
    }

    #[test]
    fn qc_encodes_with_wire_sig_size() {
        let reg = KeyRegistry::new(3, 1);
        let msg = Digest::of_bytes(b"x");
        let mut qc = QuorumCert::new(msg);
        qc.add(reg.signer(0).sign(&msg));
        qc.add(reg.signer(1).sign(&msg));
        let bytes = qc.to_bytes();
        assert_eq!(bytes.len(), qc.encoded_len());
        assert_eq!(bytes.len(), 32 + 4 + 2 * SIG_WIRE_BYTES);
        let back = QuorumCert::from_bytes(&bytes).unwrap();
        assert_eq!(back, qc);
        assert!(back.verify(&reg, 2).is_ok());
    }
}
