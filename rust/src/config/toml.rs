//! TOML-subset parser for experiment config files (the `toml` crate is
//! unavailable offline).
//!
//! Supported: `[section]` headers, `key = value` with string / integer /
//! float / boolean values, `#` comments. Values are stored flat as
//! `section.key` strings; typed access goes through the getters. This is
//! all the `defl run --config exp.toml` path needs.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    values: BTreeMap<String, String>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                let name = name.trim();
                if name.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                section = name.to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected key = value, got `{line}`", lineno + 1);
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let val = parse_value(v.trim())
                .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
            if values.insert(key.clone(), val).is_some() {
                bail!("line {}: duplicate key `{key}`", lineno + 1);
            }
        }
        Ok(TomlDoc { values })
    }

    pub fn load(path: &std::path::Path) -> Result<TomlDoc> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(s) => s.parse().map(Some).map_err(|e| anyhow!("{key}={s}: {e}")),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

fn strip_comment(line: &str) -> &str {
    // Don't strip '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<String> {
    if v.is_empty() {
        bail!("empty value");
    }
    if let Some(s) = v.strip_prefix('"') {
        let Some(inner) = s.strip_suffix('"') else {
            bail!("unterminated string");
        };
        return Ok(inner.to_string());
    }
    // bare scalar: bool / number / identifier-ish token
    if v.contains(' ') {
        bail!("unquoted value with spaces: `{v}`");
    }
    Ok(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
# experiment
rounds = 30
[model]
name = "cifar_cnn"
lr = 0.05
[attack]
kind = "gaussian:1.0"
enabled = true
"#,
        )
        .unwrap();
        assert_eq!(doc.get("rounds"), Some("30"));
        assert_eq!(doc.get("model.name"), Some("cifar_cnn"));
        assert_eq!(doc.get_parse::<f32>("model.lr").unwrap(), Some(0.05));
        assert_eq!(doc.get_parse::<bool>("attack.enabled").unwrap(), Some(true));
        assert_eq!(doc.get("missing"), None);
    }

    #[test]
    fn comments_and_hash_in_strings() {
        let doc = TomlDoc::parse("key = \"a#b\" # trailing\n").unwrap();
        assert_eq!(doc.get("key"), Some("a#b"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("just a line\n").is_err());
        assert!(TomlDoc::parse("[]\nk = 1\n").is_err());
        assert!(TomlDoc::parse("k = \"unterminated\n").is_err());
        assert!(TomlDoc::parse("k = 1\nk = 2\n").is_err());
        assert!(TomlDoc::parse("k = two words\n").is_err());
    }

    #[test]
    fn typed_errors_name_key() {
        let doc = TomlDoc::parse("k = abc\n").unwrap();
        let err = doc.get_parse::<u32>("k").unwrap_err().to_string();
        assert!(err.contains("k=abc"), "{err}");
    }
}
