//! Reader for `artifacts/manifest.txt` (written by python/compile/aot.py).
//!
//! The manifest pins the static shape metadata both sides must agree on:
//! model dimension D, batch size, class count, input shape/dtype, and the
//! (n, f) aggregation combos that were exported.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::Model;

/// Input element type of a model's data batches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum XDtype {
    F32,
    I32,
}

/// Static metadata for one model track.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    /// Flat parameter dimension D.
    pub dim: usize,
    pub batch: usize,
    pub classes: usize,
    /// Batch input shape including the leading batch dim.
    pub x_shape: Vec<usize>,
    pub x_dtype: XDtype,
}

impl ModelMeta {
    /// Elements per single example (x_shape without the batch dim).
    pub fn example_elems(&self) -> usize {
        self.x_shape[1..].iter().product()
    }

    /// Weight blob wire size in bytes (the M of §4.3).
    pub fn weight_bytes(&self) -> usize {
        self.dim * 4
    }
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelMeta>,
    /// Exported Multi-Krum (n, f) combos.
    pub nf_combos: Vec<(usize, usize)>,
    /// Exported FedAvg n values.
    pub ns: Vec<usize>,
    /// Directory the manifest was read from.
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir.to_path_buf())
    }

    /// Default artifacts directory: $DEFL_ARTIFACTS or ./artifacts.
    pub fn load_default() -> Result<Manifest> {
        let dir = std::env::var("DEFL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load(Path::new(&dir))
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let mut kv = BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("manifest: malformed line `{line}`");
            };
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }

        let mut models = BTreeMap::new();
        let names: Vec<String> = kv
            .keys()
            .filter_map(|k| k.strip_suffix(".dim").map(|s| s.to_string()))
            .collect();
        for name in names {
            let get = |suffix: &str| -> Result<&String> {
                kv.get(&format!("{name}.{suffix}"))
                    .with_context(|| format!("manifest: missing {name}.{suffix}"))
            };
            let x_shape: Vec<usize> = get("x_shape")?
                .split('x')
                .map(|s| s.parse().context("x_shape"))
                .collect::<Result<_>>()?;
            let x_dtype = match get("x_dtype")?.as_str() {
                "f32" => XDtype::F32,
                "i32" => XDtype::I32,
                other => bail!("manifest: unknown x_dtype {other}"),
            };
            models.insert(
                name.clone(),
                ModelMeta {
                    name: name.clone(),
                    dim: get("dim")?.parse()?,
                    batch: get("batch")?.parse()?,
                    classes: get("classes")?.parse()?,
                    x_shape,
                    x_dtype,
                },
            );
        }

        let nf_combos = kv
            .get("nf_combos")
            .context("manifest: missing nf_combos")?
            .split(',')
            .map(|pair| {
                let (n, f) = pair.split_once(':').context("nf pair")?;
                Ok((n.parse()?, f.parse()?))
            })
            .collect::<Result<_>>()?;
        let ns = kv
            .get("ns")
            .context("manifest: missing ns")?
            .split(',')
            .map(|s| s.parse().context("ns"))
            .collect::<Result<_>>()?;

        Ok(Manifest { models, nf_combos, ns, dir })
    }

    pub fn model(&self, m: Model) -> Result<&ModelMeta> {
        self.models
            .get(m.name())
            .with_context(|| format!("manifest: model {} not exported", m.name()))
    }

    /// Path of an artifact by stem, verified to exist.
    pub fn artifact(&self, stem: &str) -> Result<PathBuf> {
        let p = self.dir.join(format!("{stem}.hlo.txt"));
        if !p.exists() {
            bail!("artifact {} missing (run `make artifacts`)", p.display());
        }
        Ok(p)
    }

    /// Does the manifest cover the (n, f) needed by a config?
    pub fn has_krum(&self, n: usize, f: usize) -> bool {
        self.nf_combos.contains(&(n, f))
    }

    /// Does the manifest cover FedAvg at this n?
    pub fn has_fedavg(&self, n: usize) -> bool {
        self.ns.contains(&n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
cifar_cnn.dim=8794
cifar_cnn.batch=32
cifar_cnn.classes=10
cifar_cnn.x_shape=32x32x32x3
cifar_cnn.x_dtype=f32
sent_mlp.dim=33986
sent_mlp.batch=64
sent_mlp.classes=2
sent_mlp.x_shape=64x32
sent_mlp.x_dtype=i32
nf_combos=4:0,4:1,7:0,7:1,7:2,10:0,10:1,10:2,10:3
ns=4,7,10
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.models.len(), 2);
        let c = m.model(Model::CifarCnn).unwrap();
        assert_eq!(c.dim, 8794);
        assert_eq!(c.batch, 32);
        assert_eq!(c.x_shape, vec![32, 32, 32, 3]);
        assert_eq!(c.x_dtype, XDtype::F32);
        assert_eq!(c.example_elems(), 32 * 32 * 3);
        assert_eq!(c.weight_bytes(), 8794 * 4);
        let s = m.model(Model::SentMlp).unwrap();
        assert_eq!(s.x_dtype, XDtype::I32);
        assert!(m.has_krum(10, 3));
        assert!(!m.has_krum(5, 1));
        assert!(m.has_fedavg(7));
        assert!(!m.has_fedavg(5));
        assert_eq!(m.ns, vec![4, 7, 10]);
    }

    #[test]
    fn missing_fields_rejected() {
        assert!(Manifest::parse("cifar_cnn.dim=10\n", PathBuf::new()).is_err());
        assert!(Manifest::parse("nf_combos=4:1\n", PathBuf::new()).is_err());
        assert!(Manifest::parse("foo\n", PathBuf::new()).is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // Integration-level check against the actual artifacts dir; skipped
        // silently when artifacts haven't been generated yet.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.models.contains_key("cifar_cnn"));
            assert!(m.models.contains_key("sent_mlp"));
            assert!(m.artifact("train_cifar_cnn").is_ok());
        }
    }
}
