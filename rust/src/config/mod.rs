//! Experiment configuration: typed config structs, the artifact manifest
//! reader, and a TOML-subset parser for config files.

pub mod manifest;
pub mod toml;

use anyhow::{bail, Result};

/// Which model track an experiment runs (paper §5.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Model {
    /// Compact CNN standing in for DenseNet-100 on CIFAR-10.
    CifarCnn,
    /// EmbeddingBag MLP standing in for Bi-LSTM on Sentiment140.
    SentMlp,
}

impl Model {
    pub fn name(&self) -> &'static str {
        match self {
            Model::CifarCnn => "cifar_cnn",
            Model::SentMlp => "sent_mlp",
        }
    }

    pub fn parse(s: &str) -> Result<Model> {
        match s {
            "cifar_cnn" | "cifar" => Ok(Model::CifarCnn),
            "sent_mlp" | "sentiment" => Ok(Model::SentMlp),
            _ => bail!("unknown model `{s}` (cifar_cnn | sent_mlp)"),
        }
    }

    /// Default client learning rate (tuned in python/tests/test_model.py;
    /// the embedding bag needs a larger step due to 1/L pooling).
    pub fn default_lr(&self) -> f32 {
        match self {
            Model::CifarCnn => 0.05,
            Model::SentMlp => 0.8,
        }
    }
}

/// Data partition across silos (paper §5.1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Partition {
    /// Uniform iid split.
    Iid,
    /// Dirichlet(α) label-distribution skew; the paper uses α = 1.
    Dirichlet(f64),
}

impl Partition {
    pub fn parse(s: &str) -> Result<Partition> {
        if s == "iid" {
            return Ok(Partition::Iid);
        }
        if let Some(a) = s.strip_prefix("dirichlet:") {
            return Ok(Partition::Dirichlet(a.parse()?));
        }
        if s == "noniid" {
            return Ok(Partition::Dirichlet(1.0));
        }
        bail!("unknown partition `{s}` (iid | noniid | dirichlet:<alpha>)");
    }

    pub fn name(&self) -> String {
        match self {
            Partition::Iid => "iid".into(),
            Partition::Dirichlet(a) => format!("dirichlet({a})"),
        }
    }
}

/// Which system stack to run (paper §5.1 baselines + DeFL).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum System {
    /// Standard FL: central parameter server, FedAvg, no defense.
    Fl,
    /// Swarm Learning: blockchain leader election, leader aggregates.
    Swarm,
    /// Biscotti: blockchain stores all history weights, Multi-Krum filter.
    Biscotti,
    /// DeFL: per-node aggregation, HotStuff sync, τ-round storage.
    Defl,
}

impl System {
    pub const ALL: [System; 4] = [System::Fl, System::Swarm, System::Biscotti, System::Defl];

    pub fn name(&self) -> &'static str {
        match self {
            System::Fl => "FL",
            System::Swarm => "SL",
            System::Biscotti => "Biscotti",
            System::Defl => "DeFL",
        }
    }

    pub fn parse(s: &str) -> Result<System> {
        match s.to_ascii_lowercase().as_str() {
            "fl" => Ok(System::Fl),
            "sl" | "swarm" => Ok(System::Swarm),
            "biscotti" => Ok(System::Biscotti),
            "defl" => Ok(System::Defl),
            _ => bail!("unknown system `{s}` (fl | sl | biscotti | defl)"),
        }
    }

    /// FedAvg-based (FL, SL) vs Multi-Krum-based (Biscotti, DeFL).
    pub fn uses_krum(&self) -> bool {
        matches!(self, System::Biscotti | System::Defl)
    }
}

/// Threat models of §3.1 / Table 1, plus the adaptive gallery
/// ([`crate::attacks`]). `None` is the no-attack control.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Attack {
    None,
    /// Add N(0, σ²) noise to the committed weights.
    Gaussian { sigma: f32 },
    /// Commit σ·w (σ < 0) instead of w.
    SignFlip { sigma: f32 },
    /// Train on labels permuted c → (c+1) mod C.
    LabelFlip,
    /// Commit UPD with a stale round number (§3.1 "weights of the wrong
    /// round"); exercises the replica's round checks rather than accuracy.
    StaleRound,
    /// Commit AGG before GST_LT (§3.1); exercises quorum timing.
    EarlyAgg,
    /// Colluding Krum-evading perturbation: byzantine nodes commit the
    /// honest mean plus an ε-scaled shared direction, staying inside the
    /// benign score envelope so Multi-Krum selects them.
    KrumEvade { eps: f32 },
    /// Min-max AGR attack (arXiv:2409.17754): the largest γ along a
    /// shared malicious direction whose *max* distance to any benign
    /// update stays within the benign max-pairwise distance.
    MinMax,
    /// Min-sum AGR attack (arXiv:2409.17754): γ bounded by the benign
    /// *sum* of squared distances instead of the max.
    MinSum,
    /// Sync-server equivocation: a byzantine sync server answers
    /// catch-up requests with conflicting `SyncEntry` chains; exercises
    /// the chain-verified catch-up, not accuracy.
    Equivocate,
    /// Chunk-level griefing: corrupt one chunk of every multicast blob,
    /// forcing receivers onto the digest-addressed pull path.
    ChunkGrief,
}

impl Attack {
    pub fn name(&self) -> String {
        match self {
            Attack::None => "No".into(),
            Attack::Gaussian { sigma } => format!("Gaussian(s={sigma})"),
            Attack::SignFlip { sigma } => format!("Sign-flipping(s={sigma})"),
            Attack::LabelFlip => "Label-flipping".into(),
            Attack::StaleRound => "Stale-round".into(),
            Attack::EarlyAgg => "Early-AGG".into(),
            Attack::KrumEvade { eps } => format!("Krum-evade(e={eps})"),
            Attack::MinMax => "Min-max".into(),
            Attack::MinSum => "Min-sum".into(),
            Attack::Equivocate => "Equivocate".into(),
            Attack::ChunkGrief => "Chunk-grief".into(),
        }
    }

    pub fn parse(s: &str) -> Result<Attack> {
        if s == "none" {
            return Ok(Attack::None);
        }
        if s == "label-flip" {
            return Ok(Attack::LabelFlip);
        }
        if s == "stale-round" {
            return Ok(Attack::StaleRound);
        }
        if s == "early-agg" {
            return Ok(Attack::EarlyAgg);
        }
        if s == "min-max" {
            return Ok(Attack::MinMax);
        }
        if s == "min-sum" {
            return Ok(Attack::MinSum);
        }
        if s == "equivocate" {
            return Ok(Attack::Equivocate);
        }
        if s == "chunk-grief" {
            return Ok(Attack::ChunkGrief);
        }
        if let Some(v) = s.strip_prefix("gaussian:") {
            return Ok(Attack::Gaussian { sigma: v.parse()? });
        }
        if let Some(v) = s.strip_prefix("sign-flip:") {
            return Ok(Attack::SignFlip { sigma: v.parse()? });
        }
        if let Some(v) = s.strip_prefix("krum-evade:") {
            return Ok(Attack::KrumEvade { eps: v.parse()? });
        }
        bail!("unknown attack `{s}`");
    }
}

/// One experiment = system × model × scale × attack × schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    pub system: System,
    pub model: Model,
    pub partition: Partition,
    /// Total nodes n (honest + byzantine).
    pub n_nodes: usize,
    /// Byzantine nodes f (the first f node ids are adversarial).
    pub f_byzantine: usize,
    pub attack: Attack,
    /// Global training rounds T.
    pub rounds: usize,
    /// Local SGD steps per round per client.
    pub local_steps: usize,
    pub lr: f32,
    /// Training samples in the whole federation.
    pub train_samples: usize,
    /// Held-out evaluation samples.
    pub test_samples: usize,
    /// Weight rounds cached by the DeFL storage layer (τ ≥ 2, §4.3).
    pub tau: usize,
    /// Experiment RNG seed.
    pub seed: u64,
    /// Simulated per-hop latency in microseconds.
    pub link_latency_us: u64,
    /// GST_LT: local-training stabilization budget in simulated ms.
    pub gst_lt_ms: u64,
    /// Weight-blob multicast chunk budget in bytes: a blob whose wire
    /// image exceeds this is streamed as chunks and reassembled (and
    /// digest-verified) receiver-side. 0 disables chunking.
    pub chunk_bytes: usize,
    /// View-batched consensus payloads (`SubmitBatch` to the leader +
    /// pending txs piggybacked on `NewView`) instead of per-tx gossip
    /// broadcasts. Off = the legacy path, kept for overhead comparisons.
    pub batch_consensus: bool,
    /// Storage-layer pull protocol: tick period AND per-holder reply
    /// timeout for digest-addressed blob fetches (a referenced blob
    /// missing from the pool — lost chunk, healed replica — is pulled
    /// from the committing node first, rotating to other holders on
    /// timeout, miss, or a digest-mismatched reply).
    pub fetch_retry_ms: u64,
    /// Pipelined round engine: while round r sits in
    /// multicast/consensus/aggregate, speculatively train round r + 1
    /// against the already-committed W^CUR and publish the UPD the
    /// moment round r decides. One round of lookahead only, so the
    /// τ-round storage bound holds; a speculation whose basis changed is
    /// discarded, never committed, keeping final digests bit-identical
    /// to the lockstep baseline (`false` = that baseline).
    pub pipeline: bool,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            system: System::Defl,
            model: Model::CifarCnn,
            partition: Partition::Iid,
            n_nodes: 4,
            f_byzantine: 0,
            attack: Attack::None,
            rounds: 20,
            local_steps: 4,
            lr: 0.05,
            train_samples: 4096,
            test_samples: 1024,
            tau: 2,
            seed: 42,
            link_latency_us: 200,
            gst_lt_ms: 2_000,
            chunk_bytes: 256 * 1024,
            batch_consensus: true,
            fetch_retry_ms: 150,
            pipeline: true,
        }
    }
}

impl ExperimentConfig {
    /// Validate the BFT sizing constraints the analysis assumes (§4.1:
    /// n ≥ 3f + 3 for DeFL's combined client+replica fault budget, and
    /// the Krum arity n − f − 2 ≥ 1).
    pub fn validate(&self) -> Result<()> {
        if self.n_nodes == 0 {
            bail!("n_nodes must be positive");
        }
        if self.f_byzantine >= self.n_nodes {
            bail!("f must be < n");
        }
        if self.system.uses_krum() && self.n_nodes < self.f_byzantine + 3 {
            bail!(
                "multi-krum needs n - f - 2 >= 1 (n={}, f={})",
                self.n_nodes, self.f_byzantine
            );
        }
        if self.tau < 2 {
            bail!("tau must be >= 2 (current + last round)");
        }
        if self.rounds == 0 || self.local_steps == 0 {
            bail!("rounds and local_steps must be positive");
        }
        Ok(())
    }

    /// Per-round learning rate: 1/(1+0.15·r) decay stabilizes the final
    /// rounds so Table-1 style endpoint accuracies aren't oscillation
    /// noise (the paper averages 10 repetitions instead; see DESIGN.md).
    pub fn lr_at(&self, round: u64) -> f32 {
        self.lr / (1.0 + 0.15 * round as f32)
    }

    /// Krum parameter f used by aggregation artifacts: at least 1 so the
    /// filter is active even in 0-byzantine control runs (matching the
    /// paper's "Multi-Krum filters outliers even with no attack" effect).
    pub fn krum_f(&self) -> usize {
        self.f_byzantine.clamp(1, (self.n_nodes.saturating_sub(3)).max(1))
    }

    /// HotStuff replica quorum: n − f_tolerated where f_tolerated = ⌊(n−1)/3⌋.
    pub fn hotstuff_quorum(&self) -> usize {
        let f_tol = (self.n_nodes - 1) / 3;
        self.n_nodes - f_tol
    }

    /// AGG vote quorum from Algorithm 2 (f + 1).
    pub fn agg_quorum(&self) -> usize {
        self.f_byzantine + 1
    }

    pub fn label(&self) -> String {
        format!(
            "{}-{}-{}-n{}f{}-{}",
            self.system.name(),
            self.model.name(),
            self.partition.name(),
            self.n_nodes,
            self.f_byzantine,
            self.attack.name()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips() {
        assert_eq!(Model::parse("cifar_cnn").unwrap(), Model::CifarCnn);
        assert_eq!(Model::parse("sentiment").unwrap(), Model::SentMlp);
        assert!(Model::parse("bert").is_err());
        assert_eq!(System::parse("defl").unwrap(), System::Defl);
        assert_eq!(System::parse("SL").unwrap(), System::Swarm);
        assert_eq!(Partition::parse("noniid").unwrap(), Partition::Dirichlet(1.0));
        assert_eq!(
            Attack::parse("gaussian:0.03").unwrap(),
            Attack::Gaussian { sigma: 0.03 }
        );
        assert_eq!(
            Attack::parse("sign-flip:-2").unwrap(),
            Attack::SignFlip { sigma: -2.0 }
        );
        assert_eq!(
            Attack::parse("krum-evade:0.5").unwrap(),
            Attack::KrumEvade { eps: 0.5 }
        );
        assert_eq!(Attack::parse("min-max").unwrap(), Attack::MinMax);
        assert_eq!(Attack::parse("min-sum").unwrap(), Attack::MinSum);
        assert_eq!(Attack::parse("equivocate").unwrap(), Attack::Equivocate);
        assert_eq!(Attack::parse("chunk-grief").unwrap(), Attack::ChunkGrief);
    }

    #[test]
    fn default_config_is_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn validation_catches_bad_sizing() {
        let mut c = ExperimentConfig::default();
        c.n_nodes = 4;
        c.f_byzantine = 2; // krum arity: 4-2-2 = 0
        assert!(c.validate().is_err());
        c.f_byzantine = 4;
        assert!(c.validate().is_err());
        c = ExperimentConfig::default();
        c.tau = 1;
        assert!(c.validate().is_err());
    }

    #[test]
    fn quorums_match_paper() {
        let mut c = ExperimentConfig::default();
        c.n_nodes = 4;
        c.f_byzantine = 1;
        assert_eq!(c.hotstuff_quorum(), 3); // n - floor((n-1)/3) = 4 - 1
        assert_eq!(c.agg_quorum(), 2); // f + 1
        c.n_nodes = 10;
        c.f_byzantine = 3;
        assert_eq!(c.hotstuff_quorum(), 7);
        assert_eq!(c.agg_quorum(), 4);
    }

    #[test]
    fn krum_f_clamped() {
        let mut c = ExperimentConfig::default();
        c.n_nodes = 4;
        c.f_byzantine = 0;
        assert_eq!(c.krum_f(), 1); // active filter even without byzantine
        c.n_nodes = 10;
        c.f_byzantine = 3;
        assert_eq!(c.krum_f(), 3);
    }

    #[test]
    fn uses_krum_split() {
        assert!(!System::Fl.uses_krum());
        assert!(!System::Swarm.uses_krum());
        assert!(System::Biscotti.uses_krum());
        assert!(System::Defl.uses_krum());
    }
}
