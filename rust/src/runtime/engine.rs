//! The `Engine`: one PJRT CPU client plus a cache of compiled executables
//! for a model track (train / eval / init / krum_{n,f} / fedavg_n).
//!
//! Executables compile lazily on first use and are cached for the process
//! lifetime; every simulated node shares the engine (they would each own
//! one in a real deployment — weights are still passed explicitly, so
//! sharing changes no observable behaviour, only memory).

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::manifest::{Manifest, ModelMeta, XDtype};
use crate::config::Model;

/// Elements below which stacking rows on one thread beats pool dispatch.
const STACK_POOL_WORK_MIN: usize = 1 << 21;

/// A data batch in the model's input dtype.
#[derive(Debug, Clone)]
pub enum Batch {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Batch {
    pub fn len_elems(&self) -> usize {
        match self {
            Batch::F32(v) => v.len(),
            Batch::I32(v) => v.len(),
        }
    }
}

/// Output of one local SGD step.
#[derive(Debug)]
pub struct TrainOutput {
    pub theta: Vec<f32>,
    pub loss: f32,
}

/// Output of the Multi-Krum artifact.
#[derive(Debug)]
pub struct KrumResult {
    pub aggregate: Vec<f32>,
    pub scores: Vec<f32>,
    pub mask: Vec<f32>,
}

/// Which implementation served an aggregation (per-node stats surface it
/// as `agg_artifact` / `agg_native`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggPath {
    /// The AOT-compiled artifact (L1 Pallas Gram kernel through PJRT).
    Artifact,
    /// The native rust engine (`crate::krum`, blocked Gram + worker pool).
    Native,
}

pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    meta: ModelMeta,
    model: Model,
    exes: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// Executions performed, by artifact stem (profiling hook).
    exec_counts: Mutex<HashMap<String, u64>>,
    /// Wall µs spent executing, by artifact stem. Together with
    /// `exec_counts` this is the per-phase busy-time ledger the pipelined
    /// round engine reads to report how much compute it managed to hide
    /// inside the GST/consensus wait (compile time is excluded — it is a
    /// once-per-stem cost, not round work).
    exec_us: Mutex<HashMap<String, u64>>,
}

impl Engine {
    /// Create an engine for one model track from the artifact manifest.
    pub fn new(manifest: Manifest, model: Model) -> Result<Engine> {
        let meta = manifest.model(model)?.clone();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Engine {
            client,
            manifest,
            meta,
            model,
            exes: Mutex::new(HashMap::new()),
            exec_counts: Mutex::new(HashMap::new()),
            exec_us: Mutex::new(HashMap::new()),
        })
    }

    /// Engine over the default artifacts directory.
    pub fn load_default(model: Model) -> Result<Engine> {
        Engine::new(Manifest::load_default()?, model)
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    pub fn model(&self) -> Model {
        self.model
    }

    /// Flat parameter dimension D.
    pub fn dim(&self) -> usize {
        self.meta.dim
    }

    pub fn batch_size(&self) -> usize {
        self.meta.batch
    }

    fn run(&self, stem: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        // Compile-on-first-use under the cache lock; execution afterwards.
        {
            let mut exes = self.exes.lock().unwrap();
            if !exes.contains_key(stem) {
                let path = self.manifest.artifact(stem)?;
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("artifact path utf8")?,
                )
                .map_err(|e| anyhow!("parse {stem}: {e:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compile {stem}: {e:?}"))?;
                exes.insert(stem.to_string(), exe);
            }
        }
        let exes = self.exes.lock().unwrap();
        let exe = exes.get(stem).unwrap();
        let t0 = std::time::Instant::now();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {stem}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {stem}: {e:?}"))?;
        let elapsed_us = t0.elapsed().as_micros() as u64;
        *self
            .exec_counts
            .lock()
            .unwrap()
            .entry(stem.to_string())
            .or_default() += 1;
        *self.exec_us.lock().unwrap().entry(stem.to_string()).or_default() += elapsed_us;
        // aot.py lowers with return_tuple=True: always a tuple literal.
        lit.to_tuple().map_err(|e| anyhow!("untuple {stem}: {e:?}"))
    }

    pub fn exec_counts(&self) -> HashMap<String, u64> {
        self.exec_counts.lock().unwrap().clone()
    }

    /// Accumulated artifact execution wall time by stem (µs). Execution
    /// and device→host fetch only; compile-on-first-use is excluded.
    pub fn exec_us(&self) -> HashMap<String, u64> {
        self.exec_us.lock().unwrap().clone()
    }

    fn lit_f32(v: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        xla::Literal::vec1(v)
            .reshape(dims)
            .map_err(|e| anyhow!("reshape f32 {dims:?}: {e:?}"))
    }

    fn lit_i32(v: &[i32], dims: &[i64]) -> Result<xla::Literal> {
        xla::Literal::vec1(v)
            .reshape(dims)
            .map_err(|e| anyhow!("reshape i32 {dims:?}: {e:?}"))
    }

    fn batch_literal(&self, x: &Batch) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.meta.x_shape.iter().map(|&d| d as i64).collect();
        let want: usize = self.meta.x_shape.iter().product();
        match (x, self.meta.x_dtype) {
            (Batch::F32(v), XDtype::F32) => {
                if v.len() != want {
                    bail!("batch len {} != {}", v.len(), want);
                }
                Self::lit_f32(v, &dims)
            }
            (Batch::I32(v), XDtype::I32) => {
                if v.len() != want {
                    bail!("batch len {} != {}", v.len(), want);
                }
                Self::lit_i32(v, &dims)
            }
            _ => bail!("batch dtype mismatch for model {}", self.meta.name),
        }
    }

    fn check_theta(&self, theta: &[f32]) -> Result<()> {
        if theta.len() != self.meta.dim {
            bail!("theta dim {} != {}", theta.len(), self.meta.dim);
        }
        Ok(())
    }

    /// Deterministic parameter init from a seed (init artifact).
    pub fn init_params(&self, seed: u32) -> Result<Vec<f32>> {
        let seed_lit = xla::Literal::vec1(&[seed]);
        let outs = self.run(&format!("init_{}", self.meta.name), &[seed_lit])?;
        let theta = outs[0].to_vec::<f32>().map_err(|e| anyhow!("init out: {e:?}"))?;
        if theta.len() != self.meta.dim {
            bail!("init artifact produced dim {}", theta.len());
        }
        Ok(theta)
    }

    /// One SGD minibatch step (train artifact; fwd+bwd+fused Pallas update).
    pub fn train_step(&self, theta: &[f32], x: &Batch, y: &[i32], lr: f32) -> Result<TrainOutput> {
        self.check_theta(theta)?;
        if y.len() != self.meta.batch {
            bail!("y len {} != batch {}", y.len(), self.meta.batch);
        }
        let inputs = [
            xla::Literal::vec1(theta),
            self.batch_literal(x)?,
            xla::Literal::vec1(y),
            xla::Literal::vec1(&[lr]),
        ];
        let outs = self.run(&format!("train_{}", self.meta.name), &inputs)?;
        let theta = outs[0].to_vec::<f32>().map_err(|e| anyhow!("theta out: {e:?}"))?;
        let loss = outs[1].to_vec::<f32>().map_err(|e| anyhow!("loss out: {e:?}"))?[0];
        Ok(TrainOutput { theta, loss })
    }

    /// Evaluate one batch: (loss, n_correct).
    pub fn eval_batch(&self, theta: &[f32], x: &Batch, y: &[i32]) -> Result<(f32, f32)> {
        self.check_theta(theta)?;
        let inputs = [
            xla::Literal::vec1(theta),
            self.batch_literal(x)?,
            xla::Literal::vec1(y),
        ];
        let outs = self.run(&format!("eval_{}", self.meta.name), &inputs)?;
        let loss = outs[0].to_vec::<f32>().map_err(|e| anyhow!("loss: {e:?}"))?[0];
        let correct = outs[1].to_vec::<f32>().map_err(|e| anyhow!("correct: {e:?}"))?[0];
        Ok((loss, correct))
    }

    /// Does the artifact set cover Multi-Krum at (n, f)?
    pub fn has_krum(&self, n: usize, f: usize) -> bool {
        self.manifest.has_krum(n, f)
    }

    /// Stack rows into the artifact's row-major (n × D) input buffer,
    /// validating every row against the model dimension. This is the ONE
    /// copy the aggregation path pays (the PJRT literal needs contiguous
    /// input); rows come straight from the weight pool without per-row
    /// `to_vec` staging, and large stacks fan the row memcpys out over
    /// the shared worker pool.
    fn stack_checked(&self, rows: &[impl AsRef<[f32]>]) -> Result<Vec<f32>> {
        let dim = self.meta.dim;
        for (i, row) in rows.iter().enumerate() {
            if row.as_ref().len() != dim {
                bail!("row {i} dim {} != D {}", row.as_ref().len(), dim);
            }
        }
        let mut stacked = vec![0.0f32; rows.len() * dim];
        if rows.len() > 1 && rows.len() * dim >= STACK_POOL_WORK_MIN {
            let pool = crate::util::workers::global();
            let jobs: Vec<crate::util::workers::ScopedJob<'_>> = stacked
                .chunks_mut(dim)
                .zip(rows.iter())
                .map(|(dst, row)| {
                    let src: &[f32] = row.as_ref();
                    let job: crate::util::workers::ScopedJob<'_> =
                        Box::new(move || dst.copy_from_slice(src));
                    job
                })
                .collect();
            pool.scope(jobs);
        } else {
            for (dst, row) in stacked.chunks_mut(dim).zip(rows.iter()) {
                dst.copy_from_slice(row.as_ref());
            }
        }
        Ok(stacked)
    }

    /// Multi-Krum over n flat weight rows (krum artifact: the L1 Pallas
    /// Gram kernel inside the L2 selection graph).
    ///
    /// Rows are any `AsRef<[f32]>` (pool [`crate::weights::Weights`]
    /// handles, `Vec<f32>`, slices); `sample_weights` has length n.
    pub fn krum(
        &self,
        f: usize,
        rows: &[impl AsRef<[f32]>],
        sample_weights: &[f32],
    ) -> Result<KrumResult> {
        let n = rows.len();
        if sample_weights.len() != n {
            bail!("sample_weights len {} != n {}", sample_weights.len(), n);
        }
        if !self.has_krum(n, f) {
            bail!("no krum artifact for n={n} f={f} (see manifest nf_combos)");
        }
        let stacked = self.stack_checked(rows)?;
        let w = Self::lit_f32(&stacked, &[n as i64, self.meta.dim as i64])?;
        let sw = xla::Literal::vec1(sample_weights);
        let outs = self.run(&format!("krum_{}_n{n}_f{f}", self.meta.name), &[w, sw])?;
        Ok(KrumResult {
            aggregate: outs[0].to_vec::<f32>().map_err(|e| anyhow!("agg: {e:?}"))?,
            scores: outs[1].to_vec::<f32>().map_err(|e| anyhow!("scores: {e:?}"))?,
            mask: outs[2].to_vec::<f32>().map_err(|e| anyhow!("mask: {e:?}"))?,
        })
    }

    /// FedAvg over n flat weight rows (fedavg artifact).
    pub fn fedavg(&self, rows: &[impl AsRef<[f32]>], sample_weights: &[f32]) -> Result<Vec<f32>> {
        let n = rows.len();
        if sample_weights.len() != n {
            bail!("sample_weights len {} != n {}", sample_weights.len(), n);
        }
        let stacked = self.stack_checked(rows)?;
        let w = Self::lit_f32(&stacked, &[n as i64, self.meta.dim as i64])?;
        let sw = xla::Literal::vec1(sample_weights);
        let outs = self.run(&format!("fedavg_{}_n{n}", self.meta.name), &[w, sw])?;
        outs[0].to_vec::<f32>().map_err(|e| anyhow!("agg: {e:?}"))
    }

    /// Does the artifact set cover FedAvg at this n?
    pub fn has_fedavg(&self, n: usize) -> bool {
        self.manifest.has_fedavg(n)
    }

    /// The full aggregation dispatch shared by the DeFL node and the
    /// baselines: the AOT Multi-Krum artifact when exported for (n, f)
    /// — falling back to the native engine if execution fails — the
    /// native Gram Multi-Krum otherwise, and weighted FedAvg when n is
    /// too small for Krum at the given f. `f` is clamped to n − 3 so a
    /// thinned row set degrades instead of erroring.
    pub fn aggregate_robust(
        &self,
        f: usize,
        rows: &[impl AsRef<[f32]> + Sync],
        sample_weights: &[f32],
    ) -> Result<(Vec<f32>, AggPath)> {
        let n = rows.len();
        if n == 0 {
            bail!("aggregate: no rows");
        }
        let f = f.min(n.saturating_sub(3));
        if f >= 1 {
            if self.has_krum(n, f) {
                match self.krum(f, rows, sample_weights) {
                    Ok(out) => return Ok((out.aggregate, AggPath::Artifact)),
                    Err(e) => {
                        log::warn!("krum artifact failed, using native engine: {e:#}")
                    }
                }
            }
            let out = crate::krum::multi_krum(rows, sample_weights, f, n - f)?;
            Ok((out.aggregate, AggPath::Native))
        } else {
            Ok((crate::krum::fedavg(rows, sample_weights)?, AggPath::Native))
        }
    }

    /// FedAvg through the artifact when exported for this n (falling back
    /// to native on execution failure), the native fused pass otherwise.
    pub fn fedavg_auto(
        &self,
        rows: &[impl AsRef<[f32]> + Sync],
        sample_weights: &[f32],
    ) -> Result<(Vec<f32>, AggPath)> {
        let n = rows.len();
        if n == 0 {
            bail!("fedavg: no rows");
        }
        if self.has_fedavg(n) && rows[0].as_ref().len() == self.meta.dim {
            match self.fedavg(rows, sample_weights) {
                Ok(out) => return Ok((out, AggPath::Artifact)),
                Err(e) => log::warn!("fedavg artifact failed, using native: {e:#}"),
            }
        }
        Ok((crate::krum::fedavg(rows, sample_weights)?, AggPath::Native))
    }
}

/// Stack per-node flat weight rows row-major for external consumers of
/// the artifact format. All rows must share the engine's dimension.
pub fn stack_rows<R: AsRef<[f32]>>(rows: &[R]) -> Vec<f32> {
    let mut out = Vec::with_capacity(rows.iter().map(|r| r.as_ref().len()).sum());
    for r in rows {
        out.extend_from_slice(r.as_ref());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(model: Model) -> Option<Engine> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Engine::new(Manifest::load(&dir).unwrap(), model).unwrap())
    }

    fn fake_batch(e: &Engine, seed: u64) -> (Batch, Vec<i32>) {
        let mut rng = crate::util::Pcg::seeded(seed);
        let elems: usize = e.meta().x_shape.iter().product();
        let x = match e.meta().x_dtype {
            XDtype::F32 => Batch::F32((0..elems).map(|_| rng.normal_f32(0.0, 1.0)).collect()),
            XDtype::I32 => {
                Batch::I32((0..elems).map(|_| rng.gen_range(2048) as i32).collect())
            }
        };
        let y: Vec<i32> = (0..e.batch_size())
            .map(|_| rng.gen_range(e.meta().classes as u64) as i32)
            .collect();
        (x, y)
    }

    #[test]
    fn init_is_deterministic() {
        let Some(e) = engine(Model::CifarCnn) else { return };
        let a = e.init_params(7).unwrap();
        let b = e.init_params(7).unwrap();
        let c = e.init_params(8).unwrap();
        assert_eq!(a.len(), e.dim());
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn train_step_changes_params_and_yields_finite_loss() {
        let Some(e) = engine(Model::CifarCnn) else { return };
        let theta = e.init_params(1).unwrap();
        let (x, y) = fake_batch(&e, 2);
        let out = e.train_step(&theta, &x, &y, 0.05).unwrap();
        assert_eq!(out.theta.len(), e.dim());
        assert!(out.loss.is_finite());
        assert_ne!(out.theta, theta);
        // lr = 0 must be the identity (fused Pallas SGD kernel property).
        let frozen = e.train_step(&theta, &x, &y, 0.0).unwrap();
        assert_eq!(frozen.theta, theta);
        // The busy-time ledger saw both executions under the train stem.
        let counts = e.exec_counts();
        let (stem, n) = counts.iter().find(|(s, _)| s.contains("train")).unwrap();
        assert!(*n >= 2, "train stem {stem} executed {n} times");
        assert!(
            e.exec_us().values().sum::<u64>() > 0,
            "execution wall time was accounted"
        );
    }

    #[test]
    fn train_step_deterministic() {
        let Some(e) = engine(Model::SentMlp) else { return };
        let theta = e.init_params(3).unwrap();
        let (x, y) = fake_batch(&e, 4);
        let a = e.train_step(&theta, &x, &y, 0.5).unwrap();
        let b = e.train_step(&theta, &x, &y, 0.5).unwrap();
        assert_eq!(a.theta, b.theta);
        assert_eq!(a.loss, b.loss);
    }

    #[test]
    fn eval_counts_in_range() {
        let Some(e) = engine(Model::CifarCnn) else { return };
        let theta = e.init_params(5).unwrap();
        let (x, y) = fake_batch(&e, 6);
        let (loss, correct) = e.eval_batch(&theta, &x, &y).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!((0.0..=e.batch_size() as f32).contains(&correct));
    }

    #[test]
    fn krum_artifact_matches_native() {
        let Some(e) = engine(Model::CifarCnn) else { return };
        let (n, f) = (4usize, 1usize);
        let mut rng = crate::util::Pcg::seeded(11);
        let mut rows: Vec<Vec<f32>> = Vec::new();
        let center: Vec<f32> = (0..e.dim()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for _ in 0..n {
            rows.push(center.iter().map(|c| c + rng.normal_f32(0.0, 0.05)).collect());
        }
        rows[2] = rows[2].iter().map(|x| x * -4.0).collect(); // outlier
        let sw = vec![1.0f32; n];

        let art = e.krum(f, &rows, &sw).unwrap();
        let nat = crate::krum::multi_krum(&rows, &sw, f, n - f).unwrap();

        assert_eq!(art.mask, nat.mask, "selection disagrees");
        assert_eq!(art.mask[2], 0.0, "outlier not filtered");
        for (a, b) in art.aggregate.iter().zip(nat.aggregate.iter()) {
            assert!((a - b).abs() < 1e-3, "agg diverges: {a} vs {b}");
        }
        for (a, b) in art.scores.iter().zip(nat.scores.iter()) {
            let tol = 1e-3 * b.abs().max(1.0);
            assert!((a - b).abs() < tol, "score diverges: {a} vs {b}");
        }
    }

    #[test]
    fn fedavg_artifact_matches_native() {
        let Some(e) = engine(Model::CifarCnn) else { return };
        let n = 4;
        let mut rng = crate::util::Pcg::seeded(13);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..e.dim()).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let sw = [1.0f32, 2.0, 3.0, 4.0];
        let art = e.fedavg(&rows, &sw).unwrap();
        let nat = crate::krum::fedavg(&rows, &sw).unwrap();
        for (a, b) in art.iter().zip(nat.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn shape_errors_are_reported() {
        let Some(e) = engine(Model::CifarCnn) else { return };
        let theta = vec![0.0f32; 3];
        let (x, y) = fake_batch(&e, 1);
        assert!(e.train_step(&theta, &x, &y, 0.1).is_err());
        let theta = e.init_params(1).unwrap();
        assert!(e.train_step(&theta, &x, &y[..4].to_vec(), 0.1).is_err());
        let rows = vec![vec![0.0f32; e.dim()]; 5];
        assert!(e.krum(1, &rows, &[1.0; 5]).is_err()); // no artifact for n=5
        let ragged = vec![vec![0.0f32; 3]; 4];
        assert!(e.krum(1, &ragged, &[1.0; 4]).is_err()); // wrong row dim
    }
}
