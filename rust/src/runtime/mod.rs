//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`) and execute
//! them on the hot path — Python never runs after `make artifacts`.
//!
//! Flow per artifact (see /opt/xla-example/load_hlo and aot_recipe):
//! HLO text → `HloModuleProto::from_text_file` → `XlaComputation` →
//! `PjRtClient::compile` → `execute`. Text is the interchange format
//! because jax ≥ 0.5 emits 64-bit instruction ids that the pinned
//! xla_extension 0.5.1 proto path rejects.

mod engine;

pub use engine::{stack_rows, AggPath, Batch, Engine, KrumResult, TrainOutput};
