//! Biscotti baseline (Shayan et al., TPDS'21): blockchain-coordinated FL
//! with a Multi-Krum defense.
//!
//! Modelled costs (DESIGN.md substitution table):
//! * Updates travel by **flooding gossip**, as on a third-party chain
//!   platform: the origin broadcasts its update, and every node forwards
//!   each newly-seen update to all peers once. Every node therefore
//!   receives every update up to n−1 times — the "unnecessary network
//!   overhead" §2 attributes to blockchain FL, and the source of DeFL's
//!   up-to-12× receive-bandwidth win in Figure 2.
//! * The round leader assembles a block containing ALL n updates (this is
//!   what Biscotti persists), floods it, and every replica appends it —
//!   so each node's chain grows by ≈ n·M bytes EVERY round forever, vs
//!   DeFL's constant Mτn pool: the up-to-100× storage win.
//! * Aggregation is Multi-Krum over the block's updates, executed by every
//!   node identically (accuracy matches DeFL, Table 1).

use std::any::Any;
use std::collections::HashSet;
use std::sync::Arc;

use crate::attacks::{self, poison_weights};
use crate::blockchain::{Chain, ChainBlock};
use crate::config::{Attack, ExperimentConfig};
use crate::crypto::{Digest, NodeId};
use crate::defl::WeightBlob;
use crate::fl::data::{Dataset, Shard};
use crate::fl::trainer::local_train;
use crate::metrics::Traffic;
use crate::net::transport::{Actor, Ctx};
use crate::runtime::Engine;
use crate::weights::Weights;
use crate::util::codec::{decode_list, encode_list};
use crate::util::{Decode, Encode};

use super::msgs::BlMsg;

const TIMER_SEAL: u64 = 1 << 58;

pub struct BiscottiNode {
    pub id: NodeId,
    cfg: ExperimentConfig,
    engine: Arc<Engine>,
    data: Arc<Dataset>,
    shard: Shard,
    shard_sizes: Vec<f32>,
    atk_rng: crate::util::Pcg,
    attack: Attack,
    is_byzantine: bool,

    round: u64,
    theta: Vec<f32>,
    /// Updates seen for the current round (gossip-deduped); shared
    /// handles, so gossip forwarding and block assembly never copy.
    updates: Vec<Option<Weights>>,
    seen: HashSet<Digest>,
    sealed: bool,
    pub chain: Chain,

    pub done: bool,
    pub final_theta: Option<Vec<f32>>,
    pub losses: Vec<f32>,
    pub record_history: bool,
    pub theta_history: Vec<(u64, Vec<f32>)>,
}

impl BiscottiNode {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: NodeId,
        cfg: ExperimentConfig,
        engine: Arc<Engine>,
        data: Arc<Dataset>,
        mut shard: Shard,
        shard_sizes: Vec<f32>,
        theta0: Vec<f32>,
    ) -> BiscottiNode {
        let is_byzantine = (id as usize) < cfg.f_byzantine;
        let attack = if is_byzantine { cfg.attack } else { Attack::None };
        if is_byzantine && attacks::flips_labels(attack) {
            shard.flip_labels = true;
        }
        let n = cfg.n_nodes;
        let mut atk_rng = crate::util::Pcg::new(cfg.seed ^ 0xb15c, id as u64 + 1);
        atk_rng.next_u64();
        BiscottiNode {
            id,
            engine,
            data,
            shard,
            shard_sizes,
            atk_rng,
            attack,
            is_byzantine,
            round: 0,
            theta: theta0,
            updates: vec![None; n],
            seen: HashSet::new(),
            sealed: false,
            chain: Chain::new(),
            done: false,
            final_theta: None,
            losses: Vec::new(),
            record_history: false,
            theta_history: Vec::new(),
            cfg,
        }
    }

    /// Ring leader for a round (seals the block).
    fn leader(&self, round: u64) -> NodeId {
        ((round - 1) % self.cfg.n_nodes as u64) as NodeId
    }

    fn start_round(&mut self, ctx: &mut dyn Ctx, round: u64) {
        if self.done {
            return;
        }
        self.round = round;
        self.updates = vec![None; self.cfg.n_nodes];
        self.sealed = false;
        if self.record_history {
            self.theta_history.push((round - 1, self.theta.clone()));
        }
        if self.id == self.leader(round) {
            ctx.set_timer(self.cfg.gst_lt_ms * 1000 * 2, TIMER_SEAL | round);
        }
        match local_train(
            &self.engine,
            &self.data,
            &self.shard,
            round,
            self.theta.clone(),
            self.cfg.local_steps,
            self.cfg.lr_at(round - 1),
        ) {
            Ok((theta, loss)) => {
                self.theta = theta;
                self.losses.push(loss);
            }
            Err(e) => {
                log::error!("n{}: train failed: {e:#}", self.id);
                return;
            }
        }
        let mut committed = self.theta.clone();
        if self.is_byzantine {
            poison_weights(&mut committed, self.attack, &mut self.atk_rng);
        }
        let blob = WeightBlob { node: self.id, round, weights: committed.into() };
        self.note_update(&blob);
        // Flood origin: broadcast to all peers.
        ctx.broadcast(Traffic::Weights, BlMsg::Update(blob).to_bytes());
        self.maybe_seal(ctx);
    }

    /// Record an update; true if it was new (→ forward it).
    fn note_update(&mut self, blob: &WeightBlob) -> bool {
        if blob.round != self.round || self.done {
            return false;
        }
        let d = blob.digest(); // cached on the tensor
        if !self.seen.insert(d) {
            return false;
        }
        if blob.weights.len() == self.engine.dim() {
            self.updates[blob.node as usize] = Some(blob.weights.clone());
        }
        true
    }

    fn have(&self) -> usize {
        self.updates.iter().filter(|u| u.is_some()).count()
    }

    /// Leader seals once it has all updates (or on timeout).
    fn maybe_seal(&mut self, ctx: &mut dyn Ctx) {
        if self.sealed || self.done || self.id != self.leader(self.round) {
            return;
        }
        if self.have() == self.cfg.n_nodes {
            self.seal(ctx);
        }
    }

    fn seal(&mut self, ctx: &mut dyn Ctx) {
        if self.sealed || self.done {
            return;
        }
        self.sealed = true;
        // Block payload: every update of the round (Biscotti persists the
        // accepted updates in the ledger); w.clone() shares the tensor.
        let blobs: Vec<WeightBlob> = self
            .updates
            .iter()
            .enumerate()
            .filter_map(|(i, u)| {
                u.as_ref().map(|w| WeightBlob {
                    node: i as NodeId,
                    round: self.round,
                    weights: w.clone(),
                })
            })
            .collect();
        let mut payload = Vec::new();
        self.round.encode(&mut payload);
        encode_list(&blobs, &mut payload);
        let block = ChainBlock {
            height: self.chain.height() + 1,
            parent: self.chain.tip(),
            proposer: self.id,
            payload,
        };
        // Flood the block.
        ctx.broadcast(Traffic::Blocks, BlMsg::Block(block.clone()).to_bytes());
        self.apply_block(ctx, block);
    }

    /// Append the block and deterministically aggregate its updates with
    /// Multi-Krum — every node computes the identical global model.
    fn apply_block(&mut self, ctx: &mut dyn Ctx, block: ChainBlock) {
        match self.chain.append_if_new(block.clone()) {
            Ok(true) => {}
            _ => return,
        }
        let mut cur = crate::util::codec::Cursor::new(&block.payload);
        let Ok(round) = u64::decode(&mut cur) else { return };
        let Ok(blobs) = decode_list::<WeightBlob>(&mut cur) else { return };
        if round != self.round {
            return;
        }
        let mut rows: Vec<Weights> = Vec::new();
        let mut sw = Vec::new();
        for b in &blobs {
            if b.weights.len() == self.engine.dim() {
                rows.push(b.weights.clone());
                sw.push(self.shard_sizes[b.node as usize]);
            }
        }
        if rows.is_empty() {
            return;
        }
        // Same dispatch as the DeFL node: artifact Multi-Krum when
        // exported, native Gram engine otherwise, FedAvg when too few
        // rows (accuracy matches DeFL, Table 1).
        let (global, _path) = self
            .engine
            .aggregate_robust(self.cfg.krum_f(), &rows, &sw)
            .expect("biscotti aggregation");
        self.theta = global;
        if round >= self.cfg.rounds as u64 {
            self.done = true;
            self.final_theta = Some(self.theta.clone());
            return;
        }
        self.start_round(ctx, round + 1);
    }
}

impl Actor for BiscottiNode {
    fn on_start(&mut self, ctx: &mut dyn Ctx) {
        self.start_round(ctx, 1);
    }

    fn on_message(&mut self, ctx: &mut dyn Ctx, from: NodeId, _class: Traffic, bytes: &[u8]) {
        let Ok(msg) = BlMsg::from_bytes(bytes) else { return };
        match msg {
            BlMsg::Update(blob) => {
                if self.note_update(&blob) {
                    // Flood-forward newly seen updates to everyone but the
                    // sender and origin (each node forwards each item once).
                    for to in 0..ctx.n_nodes() as NodeId {
                        if to != ctx.node() && to != from && to != blob.node {
                            ctx.send(to, Traffic::Weights, BlMsg::Update(blob.clone()).to_bytes());
                        }
                    }
                    self.maybe_seal(ctx);
                }
            }
            BlMsg::Block(block) => self.apply_block(ctx, block),
            BlMsg::Global { .. } => {}
        }
    }

    fn on_timer(&mut self, ctx: &mut dyn Ctx, id: u64) {
        if id & TIMER_SEAL != 0 {
            let round = id & !TIMER_SEAL;
            if round == self.round && !self.sealed && self.have() >= 1 {
                self.seal(ctx);
            }
        }
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}
