//! FL and Swarm Learning baselines (paper §5.1).
//!
//! * **FL** (McMahan et al.): a central parameter server — colocated on
//!   node 0, as in a single-testbed deployment — collects every client's
//!   update each round, FedAvg-aggregates, and unicasts the global model
//!   back. No defense against poisoning.
//! * **SL** (Swarm Learning): identical data plane, but the aggregator is
//!   a per-round *elected leader* (hash-schedule over the cluster seed,
//!   standing in for the permissioned-blockchain election), and each round
//!   the leader appends a metadata block (round, global-model digest) that
//!   is gossiped and stored by every node. Weights never enter the chain,
//!   hence SL's ≈0 storage in Figure 2 — but the leader's bandwidth is
//!   n× every other node's, the detectability problem §2 cites.

use std::any::Any;
use std::sync::Arc;

use crate::attacks::{self, poison_weights};
use crate::blockchain::{elect_leader, Chain, ChainBlock};
use crate::config::{Attack, ExperimentConfig, System};
use crate::crypto::{Digest, NodeId};
use crate::fl::data::{Dataset, Shard};
use crate::fl::trainer::local_train;
use crate::metrics::Traffic;
use crate::net::transport::{Actor, Ctx};
use crate::runtime::Engine;
use crate::weights::Weights;
use crate::util::{Decode, Encode};

use super::msgs::BlMsg;

const TIMER_AGG_TIMEOUT: u64 = 1 << 59;

/// One node of the FL or SL baseline.
pub struct ServerFlNode {
    pub id: NodeId,
    cfg: ExperimentConfig,
    system: System,
    engine: Arc<Engine>,
    data: Arc<Dataset>,
    shard: Shard,
    shard_sizes: Vec<f32>,
    atk_rng: crate::util::Pcg,
    attack: Attack,
    is_byzantine: bool,

    /// Round currently being trained (1-based).
    round: u64,
    theta: Vec<f32>,
    /// Aggregator state: updates collected for `round` (shared handles
    /// straight off the wire — no copy per accepted update).
    collected: Vec<Option<Weights>>,
    aggregated_this_round: bool,
    /// SL: every node's copy of the metadata chain.
    pub chain: Chain,

    pub done: bool,
    pub final_theta: Option<Vec<f32>>,
    pub losses: Vec<f32>,
    pub record_history: bool,
    pub theta_history: Vec<(u64, Vec<f32>)>,
}

impl ServerFlNode {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: NodeId,
        cfg: ExperimentConfig,
        system: System,
        engine: Arc<Engine>,
        data: Arc<Dataset>,
        mut shard: Shard,
        shard_sizes: Vec<f32>,
        theta0: Vec<f32>,
    ) -> ServerFlNode {
        assert!(matches!(system, System::Fl | System::Swarm));
        let is_byzantine = (id as usize) < cfg.f_byzantine;
        let attack = if is_byzantine { cfg.attack } else { Attack::None };
        if is_byzantine && attacks::flips_labels(attack) {
            shard.flip_labels = true;
        }
        let n = cfg.n_nodes;
        let mut atk_rng = crate::util::Pcg::new(cfg.seed ^ 0xb1b1, id as u64 + 1);
        atk_rng.next_u64();
        ServerFlNode {
            id,
            system,
            engine,
            data,
            shard,
            shard_sizes,
            atk_rng,
            attack,
            is_byzantine,
            round: 0,
            theta: theta0,
            collected: vec![None; n],
            aggregated_this_round: false,
            chain: Chain::new(),
            done: false,
            final_theta: None,
            losses: Vec::new(),
            record_history: false,
            theta_history: Vec::new(),
            cfg,
        }
    }

    /// The aggregator for a round: node 0 for FL, hash-elected for SL.
    fn aggregator(&self, round: u64) -> NodeId {
        match self.system {
            System::Fl => 0,
            System::Swarm => {
                elect_leader(&Digest::of_bytes(&self.cfg.seed.to_le_bytes()), round, self.cfg.n_nodes)
            }
            _ => unreachable!(),
        }
    }

    /// Train the next round and ship the update to the aggregator.
    fn start_round(&mut self, ctx: &mut dyn Ctx, round: u64) {
        if self.done {
            return;
        }
        self.round = round;
        self.aggregated_this_round = false;
        if self.record_history {
            self.theta_history.push((round - 1, self.theta.clone()));
        }
        let agg_node = self.aggregator(round);
        if self.id == agg_node {
            self.collected = vec![None; self.cfg.n_nodes];
            // Partial-aggregation fallback if some client never reports.
            ctx.set_timer(self.cfg.gst_lt_ms * 1000 * 2, TIMER_AGG_TIMEOUT | round);
        }

        match local_train(
            &self.engine,
            &self.data,
            &self.shard,
            round,
            self.theta.clone(),
            self.cfg.local_steps,
            self.cfg.lr_at(round - 1),
        ) {
            Ok((theta, loss)) => {
                self.theta = theta;
                self.losses.push(loss);
            }
            Err(e) => {
                log::error!("n{}: train failed: {e:#}", self.id);
                return;
            }
        }
        let mut committed = self.theta.clone();
        if self.is_byzantine {
            poison_weights(&mut committed, self.attack, &mut self.atk_rng);
        }
        let blob = crate::defl::WeightBlob { node: self.id, round, weights: committed.into() };
        if self.id == agg_node {
            self.accept_update(ctx, blob);
        } else {
            ctx.send(agg_node, Traffic::Weights, BlMsg::Update(blob).to_bytes());
        }
    }

    fn accept_update(&mut self, ctx: &mut dyn Ctx, blob: crate::defl::WeightBlob) {
        if blob.round != self.round || self.aggregated_this_round || self.done {
            return;
        }
        self.collected[blob.node as usize] = Some(blob.weights);
        let have = self.collected.iter().filter(|c| c.is_some()).count();
        if have == self.cfg.n_nodes {
            self.aggregate_and_publish(ctx);
        }
    }

    fn aggregate_and_publish(&mut self, ctx: &mut dyn Ctx) {
        if self.aggregated_this_round || self.done {
            return;
        }
        self.aggregated_this_round = true;
        let mut rows = Vec::new();
        let mut sw = Vec::new();
        for (i, c) in self.collected.iter_mut().enumerate() {
            if let Some(w) = c.take() {
                rows.push(w);
                sw.push(self.shard_sizes[i]);
            }
        }
        if rows.is_empty() {
            return;
        }
        // FedAvg over everything — no defense (the Table 1 failure mode).
        // Artifact when exported for this n, native fused pass otherwise.
        let (global, _path) = self.engine.fedavg_auto(&rows, &sw).expect("fedavg");

        let round = self.round;
        if self.system == System::Swarm {
            // Metadata block: round + digest of the global model.
            let mut payload = Vec::new();
            round.encode(&mut payload);
            Digest::of_weights(&global).encode(&mut payload);
            let block = ChainBlock {
                height: self.chain.height() + 1,
                parent: self.chain.tip(),
                proposer: self.id,
                payload,
            };
            ctx.broadcast(Traffic::Blocks, BlMsg::Block(block.clone()).to_bytes());
            let _ = self.chain.append(block);
        }
        let msg = BlMsg::Global { round, weights: global.clone() };
        ctx.broadcast(Traffic::Weights, msg.to_bytes());
        self.adopt_global(ctx, round, global);
    }

    fn adopt_global(&mut self, ctx: &mut dyn Ctx, round: u64, global: Vec<f32>) {
        if self.done || round < self.round {
            return;
        }
        self.theta = global;
        if round >= self.cfg.rounds as u64 {
            self.done = true;
            self.final_theta = Some(self.theta.clone());
            return;
        }
        self.start_round(ctx, round + 1);
    }
}

impl Actor for ServerFlNode {
    fn on_start(&mut self, ctx: &mut dyn Ctx) {
        self.start_round(ctx, 1);
    }

    fn on_message(&mut self, ctx: &mut dyn Ctx, _from: NodeId, _class: Traffic, bytes: &[u8]) {
        let Ok(msg) = BlMsg::from_bytes(bytes) else { return };
        match msg {
            BlMsg::Update(blob) => self.accept_update(ctx, blob),
            BlMsg::Global { round, weights } => self.adopt_global(ctx, round, weights),
            BlMsg::Block(block) => {
                let _ = self.chain.append_if_new(block);
            }
        }
    }

    fn on_timer(&mut self, ctx: &mut dyn Ctx, id: u64) {
        if id & TIMER_AGG_TIMEOUT != 0 {
            let round = id & !TIMER_AGG_TIMEOUT;
            if round == self.round && !self.aggregated_this_round {
                self.aggregate_and_publish(ctx);
            }
        }
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}
