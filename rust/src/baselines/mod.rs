//! Baseline systems the paper compares against (§5.1): FL (central
//! parameter server), Swarm Learning (blockchain leader election), and
//! Biscotti (blockchain-stored weights + Multi-Krum).

pub mod biscotti;
pub mod msgs;
pub mod server_fl;

pub use biscotti::BiscottiNode;
pub use msgs::BlMsg;
pub use server_fl::ServerFlNode;
