//! Wire messages shared by the baseline systems.

use anyhow::Result;

use crate::blockchain::ChainBlock;
use crate::defl::WeightBlob;
use crate::util::codec::{Cursor, Decode, Encode};

/// Baseline protocol messages.
#[derive(Debug, Clone, PartialEq)]
pub enum BlMsg {
    /// Client → aggregator: a locally trained update.
    Update(WeightBlob),
    /// Aggregator → clients: the new global model.
    Global { round: u64, weights: Vec<f32> },
    /// Blockchain gossip (SL metadata blocks / Biscotti full blocks).
    Block(ChainBlock),
}

impl Encode for BlMsg {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            BlMsg::Update(b) => {
                1u8.encode(out);
                b.encode(out);
            }
            BlMsg::Global { round, weights } => {
                2u8.encode(out);
                round.encode(out);
                weights.encode(out);
            }
            BlMsg::Block(b) => {
                3u8.encode(out);
                b.encode(out);
            }
        }
    }
    fn encoded_len(&self) -> usize {
        1 + match self {
            BlMsg::Update(b) => b.encoded_len(),
            BlMsg::Global { weights, .. } => 8 + weights.encoded_len(),
            BlMsg::Block(b) => b.encoded_len(),
        }
    }
}

impl Decode for BlMsg {
    fn decode(cur: &mut Cursor<'_>) -> Result<Self> {
        Ok(match u8::decode(cur)? {
            1 => BlMsg::Update(WeightBlob::decode(cur)?),
            2 => BlMsg::Global { round: u64::decode(cur)?, weights: Vec::<f32>::decode(cur)? },
            3 => BlMsg::Block(ChainBlock::decode(cur)?),
            t => anyhow::bail!("bad baseline msg tag {t}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::Digest;

    #[test]
    fn msgs_roundtrip() {
        let msgs = vec![
            BlMsg::Update(WeightBlob { node: 1, round: 2, weights: vec![1.0, 2.0].into() }),
            BlMsg::Global { round: 3, weights: vec![-1.0; 5] },
            BlMsg::Block(ChainBlock {
                height: 1,
                parent: Digest::zero(),
                proposer: 2,
                payload: vec![9; 10],
            }),
        ];
        for m in msgs {
            let b = m.to_bytes();
            assert_eq!(b.len(), m.encoded_len());
            assert_eq!(BlMsg::from_bytes(&b).unwrap(), m);
        }
    }
}
