"""L2 aggregation graphs: Multi-Krum / FedAvg semantics.

Checks the paper-level property the whole system rests on: Krum scores rank
outliers last, so poisoned rows are excluded from the aggregate (§3.2).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import aggregate
from compile.kernels import ref

SETTLE = dict(max_examples=20, deadline=None)


def honest_cluster(n, d, seed, spread=0.1):
    rs = np.random.RandomState(seed)
    center = rs.randn(d).astype(np.float32)
    return center + spread * rs.randn(n, d).astype(np.float32), center


def test_krum_scores_match_ref():
    w = np.random.RandomState(0).randn(7, 512).astype(np.float32)
    got = np.asarray(aggregate.krum_scores(jnp.array(w), f=2))
    want = np.asarray(ref.krum_scores_ref(jnp.array(w), f=2))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-2)


@settings(**SETTLE)
@given(
    n=st.sampled_from([4, 7, 10]),
    seed=st.integers(min_value=0, max_value=10_000),
    attack_scale=st.sampled_from([10.0, 100.0]),
)
def test_multi_krum_excludes_outlier(n, seed, attack_scale):
    """One Byzantine row far from the honest cluster must get mask 0."""
    f = 1
    d = 256
    w, _ = honest_cluster(n, d, seed)
    rs = np.random.RandomState(seed + 1)
    w[0] = attack_scale * rs.randn(d).astype(np.float32)
    sw = np.ones(n, np.float32)
    agg, scores, mask = aggregate.multi_krum(
        jnp.array(w), jnp.array(sw), f=f, m=n - f)
    mask = np.asarray(mask)
    assert mask[0] == 0.0, f"byzantine row selected; scores={np.asarray(scores)}"
    assert mask.sum() == n - f


def test_multi_krum_no_attack_aggregates_cluster():
    n, f, d = 7, 1, 128
    w, center = honest_cluster(n, d, 3, spread=0.05)
    sw = np.ones(n, np.float32)
    agg, _, mask = aggregate.multi_krum(jnp.array(w), jnp.array(sw), f=f, m=n - f)
    agg = np.asarray(agg)
    # Aggregate stays within the cluster spread of the center.
    assert np.linalg.norm(agg - center) < 0.1 * np.sqrt(d)
    assert np.asarray(mask).sum() == n - f


def test_multi_krum_sign_flip_filtered():
    """Sign-flipping attack (−2·w) lands far from the cluster -> filtered."""
    n, f, d = 4, 1, 512
    w, _ = honest_cluster(n, d, 9, spread=0.05)
    w[2] = -2.0 * w[2]
    agg, _, mask = aggregate.multi_krum(
        jnp.array(w), jnp.ones(n, dtype=jnp.float32), f=f, m=n - f)
    assert np.asarray(mask)[2] == 0.0


def test_multi_krum_matches_ref_full():
    n, f = 10, 3
    w = np.random.RandomState(5).randn(n, 300).astype(np.float32)
    sw = np.random.RandomState(6).rand(n).astype(np.float32) + 0.5
    m = n - f
    agg, scores, mask = aggregate.multi_krum(jnp.array(w), jnp.array(sw), f=f, m=m)
    agg_r, scores_r, mask_r = ref.multi_krum_ref(jnp.array(w), jnp.array(sw), f=f, m=m)
    np.testing.assert_allclose(np.asarray(scores), np.asarray(scores_r),
                               rtol=1e-3, atol=1e-2)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(mask_r))
    np.testing.assert_allclose(np.asarray(agg), np.asarray(agg_r),
                               rtol=1e-4, atol=1e-5)


def test_fedavg_weighted_mean():
    w = np.stack([np.full(64, 1.0), np.full(64, 3.0)]).astype(np.float32)
    sw = np.array([1.0, 3.0], np.float32)
    (agg,) = aggregate.fedavg(jnp.array(w), jnp.array(sw))
    np.testing.assert_allclose(np.asarray(agg), 2.5, rtol=1e-6)


def test_fedavg_does_not_filter_outliers():
    """The FL/SL failure mode the paper's Table 1 shows."""
    n, d = 4, 128
    w, center = honest_cluster(n, d, 1, spread=0.01)
    w[0] = 1000.0 * np.ones(d, np.float32)
    (agg,) = aggregate.fedavg(jnp.array(w), jnp.ones(n, dtype=jnp.float32))
    assert np.linalg.norm(np.asarray(agg) - center) > 10.0


def test_krum_rejects_bad_nf():
    with pytest.raises(ValueError):
        aggregate.krum_scores(jnp.zeros((4, 8)), f=2)  # n-f-2 = 0
