"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes and value ranges; assert_allclose against ref.py
is THE correctness signal for the kernels that end up inside the exported
HLO artifacts.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.pairwise import gram, pairwise_sq_dists
from compile.kernels.sgd import sgd_update

SETTLE = dict(max_examples=25, deadline=None)


def rand(shape, seed, scale=1.0):
    return (np.random.RandomState(seed).randn(*shape) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# Gram / pairwise kernel
# ---------------------------------------------------------------------------


@settings(**SETTLE)
@given(
    n=st.integers(min_value=2, max_value=12),
    d=st.integers(min_value=1, max_value=700),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_gram_matches_ref(n, d, seed):
    w = rand((n, d), seed)
    got = np.asarray(gram(jnp.array(w), block_d=128))
    want = np.asarray(ref.gram_ref(jnp.array(w)))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)


@settings(**SETTLE)
@given(
    n=st.integers(min_value=2, max_value=10),
    d=st.integers(min_value=1, max_value=600),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([0.01, 1.0, 30.0]),
)
def test_pairwise_matches_ref(n, d, seed, scale):
    w = rand((n, d), seed, scale)
    got = np.asarray(pairwise_sq_dists(jnp.array(w), block_d=256))
    want = np.asarray(ref.pairwise_sq_dists_ref(jnp.array(w)))
    # Gram-trick cancellation costs a few ulps relative to the magnitudes.
    tol = 1e-3 * max(1.0, float(want.max()))
    np.testing.assert_allclose(got, want, atol=tol)


def test_pairwise_diag_zero():
    w = rand((6, 257), 7)
    d2 = np.asarray(pairwise_sq_dists(jnp.array(w), block_d=64))
    np.testing.assert_allclose(np.diag(d2), 0.0, atol=1e-2)


def test_pairwise_symmetric():
    w = rand((8, 333), 3)
    d2 = np.asarray(pairwise_sq_dists(jnp.array(w), block_d=64))
    np.testing.assert_allclose(d2, d2.T, atol=1e-3)


def test_gram_block_size_invariance():
    """The D-block walk must not change the result."""
    w = rand((5, 1000), 11)
    a = np.asarray(gram(jnp.array(w), block_d=64))
    b = np.asarray(gram(jnp.array(w), block_d=1024))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-3)


def test_gram_identical_rows():
    w = np.tile(rand((1, 128), 5), (4, 1))
    d2 = np.asarray(pairwise_sq_dists(jnp.array(w), block_d=64))
    np.testing.assert_allclose(d2, 0.0, atol=1e-2)


# ---------------------------------------------------------------------------
# Fused SGD kernel
# ---------------------------------------------------------------------------


@settings(**SETTLE)
@given(
    d=st.integers(min_value=1, max_value=100_000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    lr=st.sampled_from([0.0, 1e-3, 0.1, 1.0]),
)
def test_sgd_matches_ref(d, seed, lr):
    t = rand((d,), seed)
    g = rand((d,), seed + 1)
    got = np.asarray(sgd_update(jnp.array(t), jnp.array(g), lr, block=4096))
    want = np.asarray(ref.sgd_update_ref(jnp.array(t), jnp.array(g), lr))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_sgd_zero_lr_identity():
    t = rand((12345,), 1)
    g = rand((12345,), 2)
    got = np.asarray(sgd_update(jnp.array(t), jnp.array(g), 0.0))
    np.testing.assert_array_equal(got, t)


def test_sgd_block_invariance():
    t = rand((9999,), 3)
    g = rand((9999,), 4)
    a = np.asarray(sgd_update(jnp.array(t), jnp.array(g), 0.01, block=512))
    b = np.asarray(sgd_update(jnp.array(t), jnp.array(g), 0.01, block=32768))
    np.testing.assert_array_equal(a, b)
