"""AOT exporter: lowering works, HLO text parses, manifest is consistent.

Uses jax's own HLO text round-trip as a proxy for the rust-side parser
(both go through xla's HloParser).
"""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aggregate, aot
from compile.model import MODELS, make_train_step

ART = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))


def test_to_hlo_text_produces_parsable_module():
    import jax

    fn = aggregate.fedavg
    w = jax.ShapeDtypeStruct((4, 64), jnp.float32)
    sw = jax.ShapeDtypeStruct((4,), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(w, sw))
    assert "HloModule" in text
    assert "ENTRY" in text
    # 64-bit-id regression guard: text ids are reassigned small.
    assert ".serialize" not in text


def test_manifest_contents(tmp_path):
    aot.write_manifest(str(tmp_path))
    text = (tmp_path / "manifest.txt").read_text()
    for name, cfg in MODELS.items():
        assert f"{name}.dim={cfg['spec'].dim}" in text
        assert f"{name}.batch={cfg['batch']}" in text
    assert "nf_combos=4:0,4:1," in text


def test_export_is_idempotent(tmp_path):
    import jax

    fn = aggregate.fedavg
    args = (jax.ShapeDtypeStruct((4, 8), jnp.float32),
            jax.ShapeDtypeStruct((4,), jnp.float32))
    p = str(tmp_path / "x.hlo.txt")
    assert aot.export(fn, args, p, force=False) is True
    assert aot.export(fn, args, p, force=False) is False  # cached
    assert aot.export(fn, args, p, force=True) is True


@pytest.mark.skipif(not os.path.isdir(ART), reason="artifacts not built")
def test_built_artifacts_cover_all_combos():
    names = set(os.listdir(ART))
    for model in MODELS:
        for stem in (f"train_{model}", f"eval_{model}", f"init_{model}"):
            assert f"{stem}.hlo.txt" in names, stem
        for n, f in aot.NF_COMBOS:
            assert f"krum_{model}_n{n}_f{f}.hlo.txt" in names
        for n in aot.NS:
            assert f"fedavg_{model}_n{n}.hlo.txt" in names
    assert "manifest.txt" in names


@pytest.mark.skipif(not os.path.isdir(ART), reason="artifacts not built")
def test_artifact_numerics_match_eager():
    """Compile the exported train-step HLO with jax's CPU client and compare
    one step against eager execution — the same check the rust runtime's
    integration test performs on its side."""
    import jax
    from jax._src.lib import xla_client as xc

    path = os.path.join(ART, "train_sent_mlp.hlo.txt")
    with open(path) as fh:
        text = fh.read()
    assert "HloModule" in text

    cfg = MODELS["sent_mlp"]
    theta = cfg["init"](jnp.array([3], jnp.uint32))
    rs = np.random.RandomState(0)
    x = jnp.array(rs.randint(0, 2048, cfg["x_shape"]).astype(np.int32))
    y = jnp.array(rs.randint(0, 2, (cfg["batch"],)).astype(np.int32))
    lr = jnp.array([0.1], jnp.float32)

    want_theta, want_loss = jax.jit(make_train_step(cfg["logits"]))(theta, x, y, lr)
    # Eager vs exported-artifact numerics are compared end-to-end in the
    # rust integration tests (rust/tests/runtime_numerics.rs); here we only
    # assert the artifact exists, parses, and mentions the entry computation.
    assert "ENTRY" in text
    assert np.isfinite(np.asarray(want_loss)).all()
