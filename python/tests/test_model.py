"""L2 model graphs: shapes, learning signal, flat-param round-trips."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.model import (
    CIFAR_SPEC, MODELS, SENT_SPEC, ParamSpec,
    cifar_init, cifar_logits, make_eval_step, make_train_step,
    sent_init, sent_logits,
)


def test_param_spec_roundtrip():
    spec = ParamSpec((("a", (2, 3)), ("b", (4,)), ("c", (1, 1, 5))))
    assert spec.dim == 6 + 4 + 5
    theta = jnp.arange(spec.dim, dtype=jnp.float32)
    parts = spec.unflatten(theta)
    assert parts["a"].shape == (2, 3)
    assert parts["b"].shape == (4,)
    back = spec.flatten(parts)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(theta))


def test_dims_match_manifest_expectations():
    assert CIFAR_SPEC.dim == MODELS["cifar_cnn"]["spec"].dim
    assert SENT_SPEC.dim == MODELS["sent_mlp"]["spec"].dim
    # Layout changes must be deliberate: they invalidate all artifacts.
    assert CIFAR_SPEC.dim == 8794
    assert SENT_SPEC.dim == 33986


@pytest.mark.parametrize("name", list(MODELS))
def test_init_deterministic_and_finite(name):
    cfg = MODELS[name]
    seed = jnp.array([42], jnp.uint32)
    a = np.asarray(cfg["init"](seed))
    b = np.asarray(cfg["init"](seed))
    np.testing.assert_array_equal(a, b)
    assert a.shape == (cfg["spec"].dim,)
    assert np.isfinite(a).all()
    c = np.asarray(cfg["init"](jnp.array([43], jnp.uint32)))
    assert not np.array_equal(a, c)


@pytest.mark.parametrize("name", list(MODELS))
def test_logits_shape(name):
    cfg = MODELS[name]
    theta = cfg["init"](jnp.array([0], jnp.uint32))
    rs = np.random.RandomState(0)
    if cfg["x_dtype"] == jnp.float32:
        x = jnp.array(rs.randn(*cfg["x_shape"]).astype(np.float32))
    else:
        x = jnp.array(rs.randint(0, 2048, cfg["x_shape"]).astype(np.int32))
    logits = cfg["logits"](theta, x)
    assert logits.shape == (cfg["batch"], cfg["classes"])
    assert np.isfinite(np.asarray(logits)).all()


def _batch(cfg, seed=0):
    rs = np.random.RandomState(seed)
    if cfg["x_dtype"] == jnp.float32:
        x = rs.randn(*cfg["x_shape"]).astype(np.float32)
    else:
        x = rs.randint(0, 2048, cfg["x_shape"]).astype(np.int32)
    y = rs.randint(0, cfg["classes"], (cfg["batch"],)).astype(np.int32)
    return jnp.array(x), jnp.array(y)


@pytest.mark.parametrize("name,lr,steps", [("cifar_cnn", 0.05, 30),
                                           ("sent_mlp", 1.0, 100)])
def test_train_step_reduces_loss_on_fixed_batch(name, lr, steps):
    """Overfit a single batch: loss must drop clearly. The mean-pooled
    embedding bag has 1/L-scaled embedding gradients, hence the larger lr."""
    cfg = MODELS[name]
    step = jax.jit(make_train_step(cfg["logits"]))
    theta = cfg["init"](jnp.array([7], jnp.uint32))
    x, y = _batch(cfg)
    lr = jnp.array([lr], jnp.float32)
    theta, loss0 = step(theta, x, y, lr)
    for _ in range(steps):
        theta, loss = step(theta, x, y, lr)
    assert float(loss[0]) < float(loss0[0]) * 0.9, (
        f"{name}: loss {float(loss0[0])} -> {float(loss[0])}")


@pytest.mark.parametrize("name", list(MODELS))
def test_eval_step_counts(name):
    cfg = MODELS[name]
    ev = jax.jit(make_eval_step(cfg["logits"]))
    theta = cfg["init"](jnp.array([1], jnp.uint32))
    x, y = _batch(cfg, seed=3)
    loss, ncorrect = ev(theta, x, y)
    assert loss.shape == (1,) and ncorrect.shape == (1,)
    assert 0.0 <= float(ncorrect[0]) <= cfg["batch"]


def test_train_step_is_pure():
    """Same inputs -> bitwise same outputs (required for BFT determinism:
    every honest replica must compute identical aggregates, Lemma 1)."""
    cfg = MODELS["sent_mlp"]
    step = jax.jit(make_train_step(cfg["logits"]))
    theta = cfg["init"](jnp.array([5], jnp.uint32))
    x, y = _batch(cfg, seed=9)
    lr = jnp.array([0.1], jnp.float32)
    t1, l1 = step(theta, x, y, lr)
    t2, l2 = step(theta, x, y, lr)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
