"""L2: weight aggregation graphs — Multi-Krum (DeFL §3.2) and FedAvg.

These are the aggregation-side compute graphs the rust coordinator executes
on every training round. ``multi_krum`` is the DeFL/Biscotti weight filter:
Krum scores from the L1 Pallas Gram kernel, top-m selection, then a
FedAvg-style weighted mean over the selected rows. ``fedavg`` is the FL/SL
aggregation rule.

n (silo count) and f (tolerated Byzantine count) are trace-time constants,
so aot.py exports one artifact per (n, f) combination used by the paper's
tables; the rust krum/ module covers arbitrary shapes natively and
cross-checks these artifacts in tests.
"""

import jax
import jax.numpy as jnp

from compile.kernels.pairwise import pairwise_sq_dists


def krum_scores(w: jax.Array, f: int) -> jax.Array:
    """Krum score per row of w (n, D): the sum of squared distances to the
    n−f−2 closest other rows. Lower is more trustworthy."""
    n = w.shape[0]
    closest = n - f - 2
    if closest < 1:
        raise ValueError(f"krum needs n - f - 2 >= 1, got n={n} f={f}")
    d2 = pairwise_sq_dists(w)
    # Exclude self-distance by pushing the diagonal past any real distance.
    d2 = d2 + jnp.diag(jnp.full((n,), jnp.finfo(jnp.float32).max / 4, jnp.float32))
    srt = jnp.sort(d2, axis=1)
    return jnp.sum(srt[:, :closest], axis=1)


def multi_krum(w: jax.Array, sample_weights: jax.Array, f: int, m: int):
    """Multi-Krum aggregate (DeFL §3.2).

    Args:
      w: f32[n, D] stacked flat weight vectors, one row per silo.
      sample_weights: f32[n] FedAvg weights (∝ local dataset sizes).
      f: tolerated Byzantine count (trace-time constant).
      m: rows to keep (paper: top-k; we use m = n − f).

    Returns (agg f32[D], scores f32[n], mask f32[n]).
    """
    n = w.shape[0]
    scores = krum_scores(w, f)
    # mask = 1 for the m smallest scores. Threshold at the m-th order
    # statistic; strict ranking tie-break via argsort for determinism.
    order = jnp.argsort(scores)
    mask = jnp.zeros((n,), jnp.float32).at[order[:m]].set(1.0)
    sw = sample_weights.astype(jnp.float32) * mask
    agg = (sw[:, None] * w).sum(axis=0) / jnp.maximum(sw.sum(), 1e-12)
    return agg, scores, mask


def fedavg(w: jax.Array, sample_weights: jax.Array):
    """FedAvg (McMahan et al.): weighted mean of all rows."""
    sw = sample_weights.astype(jnp.float32)
    agg = (sw[:, None] * w).sum(axis=0) / jnp.maximum(sw.sum(), 1e-12)
    return (agg,)


def make_multi_krum(n: int, f: int, m: int):
    """Trace-time wrapper returning a 2-arg fn for aot export."""

    def fn(w, sample_weights):
        return multi_krum(w, sample_weights, f, m)

    fn.__name__ = f"multi_krum_n{n}_f{f}_m{m}"
    return fn
