"""Pure-jnp oracles for the Pallas kernels (pytest compares against these).

Every kernel in this package has a reference here computed with plain
jax.numpy — no Pallas, no blocking — serving as the correctness ground
truth for python/tests/test_kernels.py (hypothesis sweeps shapes/dtypes).
"""

import jax.numpy as jnp


def gram_ref(w):
    """G = W·Wᵀ, f32 accumulate."""
    w = w.astype(jnp.float32)
    return w @ w.T


def pairwise_sq_dists_ref(w):
    """Pairwise squared distances via direct elementwise differences."""
    w = w.astype(jnp.float32)
    diff = w[:, None, :] - w[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def sgd_update_ref(theta, grad, lr):
    return theta.astype(jnp.float32) - jnp.float32(lr) * grad.astype(jnp.float32)


def krum_scores_ref(w, f):
    """Krum score per row: sum of squared distances to its n−f−2 closest
    peers (self excluded), per Blanchard et al. and DeFL §3.2."""
    n = w.shape[0]
    closest = n - f - 2
    assert closest >= 1, "krum needs n - f - 2 >= 1"
    d2 = pairwise_sq_dists_ref(w)
    d2 = d2 + jnp.diag(jnp.full((n,), jnp.inf, dtype=jnp.float32))
    srt = jnp.sort(d2, axis=1)
    return jnp.sum(srt[:, :closest], axis=1)


def multi_krum_ref(w, sample_weights, f, m):
    """Multi-Krum aggregate: FedAvg (weighted by sample_weights) over the m
    rows with the smallest Krum scores. Returns (agg, scores, mask)."""
    scores = krum_scores_ref(w, f)
    order = jnp.argsort(scores)
    sel = order[:m]
    mask = jnp.zeros((w.shape[0],), jnp.float32).at[sel].set(1.0)
    sw = sample_weights.astype(jnp.float32) * mask
    agg = (sw[:, None] * w.astype(jnp.float32)).sum(0) / jnp.maximum(sw.sum(), 1e-12)
    return agg, scores, mask
