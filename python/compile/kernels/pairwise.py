"""L1 Pallas kernel: tiled Gram-matrix accumulation for Multi-Krum.

The Multi-Krum weight filter (DeFL §3.2) needs the full pairwise
squared-distance matrix over the n stacked flat weight vectors W ∈ R^{n×D}.
D is the model dimension (10^4..10^7), n is the silo count (4..10), so the
hot spot is the contraction over D.

GPU→TPU adaptation (DESIGN.md §Hardware-Adaptation): instead of the CUDA
threadblock/shared-memory tiling a GPU implementation would use, we compute
the Gram matrix G = W·Wᵀ with a Pallas kernel whose grid walks D in
VMEM-sized blocks and accumulates an (n_pad, n_pad) f32 tile directly in the
output ref; the per-block contraction is an (n_pad, BLK_D)×(BLK_D, n_pad)
matmul that maps onto the MXU systolic array. Squared distances follow from
    dist²(i, j) = G_ii + G_jj − 2·G_ij
outside the kernel (O(n²) work, negligible).

Kernels are lowered with interpret=True: the CPU PJRT client cannot execute
Mosaic custom-calls, and interpret mode lowers the same schedule to plain
HLO (a while-loop over the grid), so numerics and the HBM↔VMEM block
schedule are both exercised.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default D-block. 8 rows × 4096 f32 = 128 KiB per operand block; with
# double buffering the kernel's VMEM footprint stays ≪ 16 MiB for n ≤ 16.
# See EXPERIMENTS.md §Perf for the footprint/utilization table.
DEFAULT_BLOCK_D = 4096

# Pad n up to the TPU sublane count so the MXU tile is well-shaped.
ROW_PAD = 8


def _pad_rows(n: int) -> int:
    return max(ROW_PAD, ((n + ROW_PAD - 1) // ROW_PAD) * ROW_PAD)


def _gram_kernel(w_ref, o_ref):
    """One grid step: accumulate W_blk · W_blkᵀ into the (n_pad, n_pad) output.

    The output BlockSpec maps every grid step to the same (0, 0) block, so
    o_ref acts as a VMEM-resident accumulator across the D-walk.
    """
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    blk = w_ref[...]
    # (n_pad, BLK_D) @ (BLK_D, n_pad) -> MXU contraction.
    o_ref[...] += jnp.dot(blk, blk.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_d",))
def gram(w: jax.Array, block_d: int = DEFAULT_BLOCK_D) -> jax.Array:
    """Gram matrix G = W·Wᵀ for W of shape (n, D), via the Pallas kernel.

    Pads n to the sublane multiple and D to a multiple of block_d (zero
    padding changes neither G nor the derived distances), runs the blocked
    accumulation, and slices back to (n, n).
    """
    n, d = w.shape
    n_pad = _pad_rows(n)
    d_pad = ((d + block_d - 1) // block_d) * block_d
    wp = jnp.pad(w, ((0, n_pad - n), (0, d_pad - d)))
    nblocks = d_pad // block_d

    out = pl.pallas_call(
        _gram_kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((n_pad, block_d), lambda k: (0, k))],
        out_specs=pl.BlockSpec((n_pad, n_pad), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, n_pad), jnp.float32),
        interpret=True,
    )(wp)
    return out[:n, :n]


def pairwise_sq_dists(w: jax.Array, block_d: int = DEFAULT_BLOCK_D) -> jax.Array:
    """Pairwise squared euclidean distances between rows of W, shape (n, n).

    dist²(i,j) = G_ii + G_jj − 2 G_ij, clamped at 0 against rounding."""
    g = gram(w, block_d=block_d)
    sq = jnp.diag(g)
    d2 = sq[:, None] + sq[None, :] - 2.0 * g
    return jnp.maximum(d2, 0.0)
