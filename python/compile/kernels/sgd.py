"""L1 Pallas kernel: fused SGD parameter update.

The train-path hot spot after the backward pass is the elementwise update
    theta' = theta − lr · grad
over the flat parameter vector (DESIGN.md flat-parameter convention). On a
GPU this is a trivially coalesced elementwise kernel; on TPU it is a pure
VPU pass that we block along D so each step streams one VMEM-sized slab of
theta and grad. Fusing the update into one kernel avoids materializing the
scaled gradient. interpret=True for the same reason as pairwise.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 8 × 4096 f32 = 128 KiB per operand slab.
DEFAULT_BLOCK = 32768
_LANES = 128


def _sgd_kernel(lr_ref, t_ref, g_ref, o_ref):
    o_ref[...] = t_ref[...] - lr_ref[0] * g_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def sgd_update(theta: jax.Array, grad: jax.Array, lr: jax.Array,
               block: int = DEFAULT_BLOCK) -> jax.Array:
    """theta − lr·grad over a flat f32[D] vector via the blocked kernel.

    D is zero-padded to a multiple of the block, reshaped to
    (D_pad/128, 128) so the last axis fills the VPU lanes, updated
    block-row-wise, and sliced back.
    """
    (d,) = theta.shape
    lr = jnp.asarray(lr, jnp.float32).reshape((1,))
    rows_per_blk = max(block // _LANES, 1)
    d_pad = ((d + block - 1) // block) * block
    rows = d_pad // _LANES

    tp = jnp.pad(theta, (0, d_pad - d)).reshape(rows, _LANES)
    gp = jnp.pad(grad, (0, d_pad - d)).reshape(rows, _LANES)
    nblocks = rows // rows_per_blk

    out = pl.pallas_call(
        _sgd_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1,), lambda k: (0,)),
            pl.BlockSpec((rows_per_blk, _LANES), lambda k: (k, 0)),
            pl.BlockSpec((rows_per_blk, _LANES), lambda k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((rows_per_blk, _LANES), lambda k: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, _LANES), jnp.float32),
        interpret=True,
    )(lr, tp, gp)
    return out.reshape(d_pad)[:d]
