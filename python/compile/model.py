"""L2: jax model definitions with the flat-parameter convention.

Two model tracks mirror the paper's two evaluation tracks (§5.1):

* ``cifar_cnn`` — compact CNN for 32×32×3 10-class image classification.
  Stands in for DenseNet-100 on CIFAR-10 (see DESIGN.md substitution table:
  the table-level phenomena depend on gradient geometry, not on DenseNet).
* ``sent_mlp`` — EmbeddingBag + MLP for 2-class token-sequence sentiment.
  Stands in for Word2Vec + attention Bi-LSTM on Sentiment140.

Every model exposes its parameters as ONE flat f32[D] vector; the rust
coordinator only ever sees flat buffers (it hashes them into UPD
transactions, stacks them into the f32[n,D] Multi-Krum input, and feeds the
aggregate back). ``ParamSpec`` records the (name, shape) layout so the
traced train/eval steps can unflatten with static slices.

The SGD application itself goes through the L1 fused Pallas kernel
(kernels/sgd.py) so that the kernel lowers into the same train-step HLO.
"""

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.sgd import sgd_update

# ---------------------------------------------------------------------------
# Flat-parameter plumbing
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Static layout of a model's parameters inside the flat vector."""

    entries: Tuple[Tuple[str, Tuple[int, ...]], ...]

    @property
    def dim(self) -> int:
        total = 0
        for _, shape in self.entries:
            size = 1
            for s in shape:
                size *= s
            total += size
        return total

    def offsets(self) -> List[Tuple[str, int, int, Tuple[int, ...]]]:
        out, off = [], 0
        for name, shape in self.entries:
            size = 1
            for s in shape:
                size *= s
            out.append((name, off, size, shape))
            off += size
        return out

    def unflatten(self, theta: jax.Array) -> Dict[str, jax.Array]:
        return {
            name: jax.lax.slice(theta, (off,), (off + size,)).reshape(shape)
            for name, off, size, shape in self.offsets()
        }

    def flatten(self, params: Dict[str, jax.Array]) -> jax.Array:
        return jnp.concatenate(
            [params[name].reshape(-1) for name, _ in self.entries]
        ).astype(jnp.float32)


# ---------------------------------------------------------------------------
# CIFAR track: compact CNN
# ---------------------------------------------------------------------------

CIFAR_IMG = (32, 32, 3)
CIFAR_CLASSES = 10
CIFAR_BATCH = 32

CIFAR_SPEC = ParamSpec(
    entries=(
        ("conv1_w", (3, 3, 3, 8)),
        ("conv1_b", (8,)),
        ("conv2_w", (3, 3, 8, 16)),
        ("conv2_b", (16,)),
        ("conv3_w", (3, 3, 16, 32)),
        ("conv3_b", (32,)),
        ("fc1_w", (32, 64)),
        ("fc1_b", (64,)),
        ("fc2_w", (64, CIFAR_CLASSES)),
        ("fc2_b", (CIFAR_CLASSES,)),
    )
)


def _conv(x, w, b, stride=1):
    y = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def _avgpool2(x):
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    ) / 4.0


def cifar_logits(theta: jax.Array, x: jax.Array) -> jax.Array:
    """x: f32[B,32,32,3] -> logits f32[B,10]."""
    p = CIFAR_SPEC.unflatten(theta)
    h = jax.nn.relu(_conv(x, p["conv1_w"], p["conv1_b"]))
    h = _avgpool2(h)                      # 16x16x8
    h = jax.nn.relu(_conv(h, p["conv2_w"], p["conv2_b"]))
    h = _avgpool2(h)                      # 8x8x16
    h = jax.nn.relu(_conv(h, p["conv3_w"], p["conv3_b"]))
    h = jnp.mean(h, axis=(1, 2))          # global average pool -> [B,32]
    h = jax.nn.relu(h @ p["fc1_w"] + p["fc1_b"])
    return h @ p["fc2_w"] + p["fc2_b"]


def cifar_init(seed: jax.Array) -> jax.Array:
    """He-style init of the flat parameter vector from a u32[1] seed."""
    key = jax.random.PRNGKey(seed[0])
    params = {}
    for name, shape in CIFAR_SPEC.entries:
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = 1
            for s in shape[:-1]:
                fan_in *= s
            params[name] = jax.random.normal(sub, shape, jnp.float32) * jnp.sqrt(
                2.0 / fan_in
            )
    return CIFAR_SPEC.flatten(params)


# ---------------------------------------------------------------------------
# Sentiment track: EmbeddingBag + MLP
# ---------------------------------------------------------------------------

SENT_VOCAB = 2048
SENT_LEN = 32
SENT_EMBED = 16
SENT_HIDDEN = 64
SENT_CLASSES = 2
SENT_BATCH = 64

SENT_SPEC = ParamSpec(
    entries=(
        ("embed", (SENT_VOCAB, SENT_EMBED)),
        ("fc1_w", (SENT_EMBED, SENT_HIDDEN)),
        ("fc1_b", (SENT_HIDDEN,)),
        ("fc2_w", (SENT_HIDDEN, SENT_CLASSES)),
        ("fc2_b", (SENT_CLASSES,)),
    )
)


def sent_logits(theta: jax.Array, x: jax.Array) -> jax.Array:
    """x: i32[B,L] token ids -> logits f32[B,2]. Mean-pooled embedding bag."""
    p = SENT_SPEC.unflatten(theta)
    emb = jnp.take(p["embed"], x, axis=0)  # [B,L,E]
    h = jnp.mean(emb, axis=1)              # [B,E]
    h = jnp.tanh(h @ p["fc1_w"] + p["fc1_b"])
    return h @ p["fc2_w"] + p["fc2_b"]


def sent_init(seed: jax.Array) -> jax.Array:
    key = jax.random.PRNGKey(seed[0])
    params = {}
    for name, shape in SENT_SPEC.entries:
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name == "embed":
            params[name] = jax.random.normal(sub, shape, jnp.float32) * 0.1
        else:
            params[name] = jax.random.normal(sub, shape, jnp.float32) * jnp.sqrt(
                2.0 / shape[0]
            )
    return SENT_SPEC.flatten(params)


# ---------------------------------------------------------------------------
# Shared train / eval steps
# ---------------------------------------------------------------------------


def _xent(logits: jax.Array, y: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def make_train_step(logits_fn):
    """(theta f32[D], x, y i32[B], lr f32[1]) -> (theta' f32[D], loss f32[1]).

    Forward + backward with jax.value_and_grad; the parameter update runs
    through the fused Pallas SGD kernel so L1 lowers into this HLO module.
    """

    def loss_fn(theta, x, y):
        return _xent(logits_fn(theta, x), y)

    def train_step(theta, x, y, lr):
        loss, grad = jax.value_and_grad(loss_fn)(theta, x, y)
        new_theta = sgd_update(theta, grad, lr[0])
        return new_theta, loss.reshape((1,))

    return train_step


def make_eval_step(logits_fn):
    """(theta, x, y) -> (loss f32[1], ncorrect f32[1])."""

    def eval_step(theta, x, y):
        logits = logits_fn(theta, x)
        loss = _xent(logits, y)
        correct = jnp.sum((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
        return loss.reshape((1,)), correct.reshape((1,))

    return eval_step


# Registry consumed by aot.py and the tests.
MODELS = {
    "cifar_cnn": dict(
        spec=CIFAR_SPEC,
        logits=cifar_logits,
        init=cifar_init,
        batch=CIFAR_BATCH,
        x_shape=(CIFAR_BATCH,) + CIFAR_IMG,
        x_dtype=jnp.float32,
        classes=CIFAR_CLASSES,
    ),
    "sent_mlp": dict(
        spec=SENT_SPEC,
        logits=sent_logits,
        init=sent_init,
        batch=SENT_BATCH,
        x_shape=(SENT_BATCH, SENT_LEN),
        x_dtype=jnp.int32,
        classes=SENT_CLASSES,
    ),
}
